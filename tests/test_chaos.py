"""Deterministic fault injection + crash-safe tunedb (PR 10).

Pins the robustness contracts: the chaos shim is zero-cost and invisible
while disarmed (monkeypatch-proven); per-line CRCs catch silent corruption
and old CRC-less stores still load; torn/garbage lines are quarantined —
never served, never lost; a SIGKILLed appender loses nothing it
acknowledged; the lease protocol under seeded fault plans still finishes
every job exactly once; ``retry_io`` retries transient errno, never
genuine races; ``tunedb fsck`` detects and repairs each damage class; and
the serving layer degrades gracefully (deadlines, shedding, /healthz 503,
retune watchdog) instead of wedging.
"""

import errno
import json
import pathlib
import signal
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request

import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import SearchResult
from repro.core.space import gemm_input
from repro.tunedb import chaos
from repro.tunedb.__main__ import main as tunedb_main
from repro.tunedb.chaos import (FaultPlan, FaultRule, KillPoint, retry_io,
                                TRANSIENT_ERRNOS)
from repro.tunedb.fleet import Coordinator, FleetJob, Worker
from repro.tunedb.store import RecordStore, TuneRecord

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _disarmed():
    """Chaos must never leak across tests (the shim is process-global)."""
    chaos.disarm()
    yield
    chaos.disarm()


def _rec(i: int = 0, tflops: float = 1.0) -> TuneRecord:
    return TuneRecord(space="gemm", inputs=gemm_input(128 * (i + 1), 64, 512),
                      config=dict(CFG), tflops=tflops, backend="sim")


class StubTuner:
    """Instant deterministic tuner: chaos tests are about I/O, not search."""

    space = None
    backend = SimulatedTPUBackend(noise=0.0)

    def search(self, inputs, remeasure=True):
        tf = float(self.backend.measure("gemm", CFG, inputs))
        return SearchResult(best=dict(CFG), predicted_tflops=tf,
                            measured_tflops=tf, top_k=[(dict(CFG), tf)],
                            n_candidates=1, measured=[(dict(CFG), tf)])


# ---------------------------------------------------------------------------
# CRC + quarantine + repair (crash-safe RecordStore)
# ---------------------------------------------------------------------------

def test_crc_roundtrip_and_mismatch():
    rec = _rec()
    line = rec.to_json()
    assert json.loads(line)["crc"]
    assert TuneRecord.from_json(line).tflops == rec.tflops
    doc = json.loads(line)
    doc["tflops"] = 99.0                    # bit-flip after the CRC stamp
    with pytest.raises(ValueError, match="CRC"):
        TuneRecord.from_json(json.dumps(doc))


def test_crcless_legacy_line_still_loads(tmp_path):
    """Schema stays additive: stores written before the crc field load."""
    doc = json.loads(_rec().to_json())
    doc.pop("crc")
    legacy = tmp_path / "old.jsonl"
    legacy.write_text(json.dumps(doc) + "\n")
    s = RecordStore.open(legacy)
    assert len(s) == 1 and s.n_skipped == 0


def test_load_quarantines_garbage_and_repair_rewrites(tmp_path):
    path = tmp_path / "db.jsonl"
    s = RecordStore(path)
    s.add(_rec(0))
    s.add(_rec(1))
    with path.open("a", encoding="utf-8") as fh:
        fh.write('{"torn half-line\n')
        fh.write(_rec(2).to_json() + "\n")
    with pytest.warns(RuntimeWarning, match="quarantined"):
        s2 = RecordStore.open(path)
    assert len(s2) == 3 and s2.n_skipped == 1
    qdir = s2.quarantine_dir()
    assert qdir.is_dir()
    quarantined = list(qdir.glob("*-load.jsonl"))
    assert len(quarantined) == 1
    assert "torn half-line" in quarantined[0].read_text()
    # repair rewrites the file: the next load is clean, nothing lost
    out = s2.repair()
    assert out == {"kept": 3, "quarantined": 1}
    s3 = RecordStore.open(path)
    assert len(s3) == 3 and s3.n_skipped == 0
    # the rewritten store appends correctly (newline bookkeeping intact)
    s3.add(_rec(3))
    assert len(RecordStore.open(path)) == 4


def test_quarantine_warns_once_per_store(tmp_path):
    import warnings as _w
    path = tmp_path / "db.jsonl"
    RecordStore(path).add(_rec())
    with path.open("a") as fh:
        fh.write("garbage\n")
    with pytest.warns(RuntimeWarning):
        RecordStore.open(path)
    with path.open("a") as fh:
        fh.write("more garbage\n")
    with _w.catch_warnings():
        _w.simplefilter("error")            # second load: silent
        RecordStore.open(path)


# ---------------------------------------------------------------------------
# retry_io policy
# ---------------------------------------------------------------------------

def test_retry_io_retries_transient_errno():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError(errno.EIO, "injected")
        return "ok"

    assert retry_io(flaky, site="t", base_delay_s=0.0) == "ok"
    assert calls["n"] == 3


def test_retry_io_gives_up_after_budget():
    def always():
        raise OSError(errno.EIO, "injected")

    with pytest.raises(OSError):
        retry_io(always, site="t", attempts=3, base_delay_s=0.0)


@pytest.mark.parametrize("exc", [
    FileNotFoundError(errno.ENOENT, "lost race"),
    OSError(errno.ENOSPC, "disk full"),
])
def test_retry_io_never_retries_nontransient(exc):
    """A lost rename race or a full disk is not transient: fail fast so
    the protocol-level recovery (requeue, degrade) runs instead."""
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        raise exc

    with pytest.raises(type(exc)):
        retry_io(fn, site="t", base_delay_s=0.0)
    assert calls["n"] == 1
    assert exc.errno not in TRANSIENT_ERRNOS or isinstance(
        exc, FileNotFoundError)


# ---------------------------------------------------------------------------
# the shim is invisible while disarmed (E19's zero-cost criterion)
# ---------------------------------------------------------------------------

def test_zero_shim_calls_while_disarmed(tmp_path, monkeypatch):
    """Monkeypatch-proven: with no plan armed, the store append/load, the
    full lease lifecycle, and plan export/load make ZERO FaultyIO calls."""
    hits = {"n": 0}

    def trap(self, *a, **kw):
        hits["n"] += 1
        raise AssertionError("disarmed path touched the chaos shim")

    for name in ("probe", "read_text", "read_bytes", "write_text",
                 "write_bytes", "file_write", "replace", "rename",
                 "fsync", "utime", "unlink"):
        monkeypatch.setattr(chaos.FaultyIO, name, trap)
    assert chaos._IO is None

    store = RecordStore(tmp_path / "db.jsonl")
    store.add(_rec())
    RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store, lease_timeout_s=5.0)
    coord.publish([FleetJob(space="gemm", inputs=gemm_input(128, 64, 512))])
    fd = coord.fleet
    job, lp = fd.claim()
    fd.heartbeat(lp)
    fd.complete(job, lp, {"worker_id": "w"})
    from repro.tunedb.plans import export_plan, load_plan
    from repro.tunedb.store import DispatchPlan
    plan = DispatchPlan(generation=0, fingerprint="sim", store_version=-1,
                        table={("gemm", (("M", 128),)): (dict(CFG), "exact")})
    load_plan(export_plan(plan, tmp_path / "plan"))
    assert hits["n"] == 0


# ---------------------------------------------------------------------------
# determinism + kill-points
# ---------------------------------------------------------------------------

def test_same_seed_same_faults(tmp_path):
    def run(seed):
        plan = FaultPlan(seed=seed, rules=[
            FaultRule(site="store.append", kind="errno", p=0.5,
                      errno=errno.EIO)])
        path = tmp_path / f"s{seed}-{time.monotonic_ns()}.jsonl"
        s = RecordStore(path)
        outcomes = []
        with chaos.armed(plan) as io:
            for i in range(20):
                try:
                    s.add(_rec(i))
                    outcomes.append("ok")
                except OSError:
                    outcomes.append("eio")
        return outcomes, io.report()

    a_out, a_rep = run(7)
    b_out, b_rep = run(7)
    c_out, _ = run(8)
    assert a_out == b_out and a_rep["injected_total"] == b_rep[
        "injected_total"]
    assert "eio" in a_out and "ok" in a_out     # p=0.5 actually mixes
    assert a_out != c_out                        # different seed differs


def test_kill_point_is_not_swallowed_by_job_isolation(tmp_path):
    """KillPoint derives from BaseException: the worker's `except
    Exception` job isolation must NOT absorb a simulated crash."""
    store = RecordStore(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store, lease_timeout_s=0.2)
    coord.publish([FleetJob(space="gemm", inputs=gemm_input(128, 64, 512))])
    w = Worker(tmp_path / "fleet", worker_id="doomed",
               tuners={"gemm": StubTuner()}, poll_s=0.01, heartbeat_s=0.05)
    plan = FaultPlan(seed=0, rules=[
        FaultRule(site="worker.tuned", kind="kill", p=1.0, max_count=1)])
    with chaos.armed(plan):
        with pytest.raises(KillPoint):
            w.run_one()
    # the lease the dead worker held expires and the job requeues
    time.sleep(0.25)
    assert coord.fleet.reclaim_expired(
        lease_timeout_s=0.2, max_attempts=3)
    assert coord.fleet.counts()["queue"] == 1


def test_torn_append_quarantined_on_reload(tmp_path):
    path = tmp_path / "db.jsonl"
    s = RecordStore(path, fsync=True)
    s.add(_rec(0))
    plan = FaultPlan(seed=0, rules=[
        FaultRule(site="store.append", kind="torn_write", p=1.0,
                  max_count=1)])
    with chaos.armed(plan):
        with pytest.raises(KillPoint):
            s.add(_rec(1))
    # the "crashed" process's file has a torn tail; a fresh open serves
    # every complete record and quarantines the fragment
    with pytest.warns(RuntimeWarning, match="quarantined"):
        s2 = RecordStore.open(path)
    assert len(s2) == 1
    assert s2.records()[0].inputs == _rec(0).inputs
    # and the store keeps working after the crash
    s2.add(_rec(2))
    assert len(RecordStore.open(path)) == 2


# ---------------------------------------------------------------------------
# SIGKILL mid-append: nothing acknowledged is ever lost
# ---------------------------------------------------------------------------

_CHILD = """
import sys
sys.path.insert(0, {src!r})
from repro.tunedb.store import RecordStore, TuneRecord
s = RecordStore({path!r}, fsync=True)
i = 0
while True:
    s.add(TuneRecord(space="gemm", inputs={{"M": i, "N": 64, "K": 512}},
                     config={{"bm": 32}}, tflops=1.0, backend="sim"))
    print(i, flush=True)        # ACK: durable before this line prints
    i += 1
"""


def test_sigkill_mid_append_recovers_all_acked(tmp_path):
    path = str(tmp_path / "db.jsonl")
    proc = subprocess.Popen(
        [sys.executable, "-c", _CHILD.format(src=SRC, path=path)],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True)
    acked = []
    try:
        for line in proc.stdout:
            acked.append(int(line))
            if len(acked) >= 12:
                proc.send_signal(signal.SIGKILL)   # mid-flight, no cleanup
                break
    finally:
        proc.kill()
        proc.wait(timeout=30)
    assert len(acked) >= 12
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("ignore")       # a torn tail line may warn
        s = RecordStore.open(path)
    recovered = {r.inputs["M"] for r in s.records()}
    missing = set(acked) - recovered
    assert not missing, f"acked records lost after SIGKILL: {missing}"


# ---------------------------------------------------------------------------
# seeded chaos property test: the lease protocol finishes every job once
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [11, 23, 47])
def test_lease_protocol_survives_seeded_chaos(tmp_path, seed):
    store = RecordStore(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store, lease_timeout_s=0.3)
    jobs = [FleetJob(space="gemm", inputs=gemm_input(128 * (i + 1), 64, 512))
            for i in range(6)]
    assert coord.publish(jobs) == 6
    plan = FaultPlan(seed=seed, rules=[
        FaultRule(site="worker.*", kind="kill", p=0.15, max_count=2),
        FaultRule(site="lease.*", kind="errno", p=0.10, errno=errno.EIO,
                  max_count=6),
        FaultRule(site="store.append", kind="torn_write", p=0.05,
                  max_count=1),
    ])

    def run_worker(wid):
        w = Worker(tmp_path / "fleet", worker_id=wid,
                   tuners={"gemm": StubTuner()}, poll_s=0.01,
                   heartbeat_s=0.05)
        try:
            w.run(max_jobs=8, idle_timeout_s=0.5)
        except KillPoint:
            pass                             # simulated crash: thread dies

    with chaos.armed(plan) as io:
        threads = [threading.Thread(target=run_worker, args=(f"w{i}",))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
    assert io.calls > 0                      # the plan actually engaged

    # recovery phase, faults off: requeue expired leases, drain the rest
    deadline = time.time() + 30
    while time.time() < deadline:
        time.sleep(0.31)
        coord.fleet.reclaim_expired(lease_timeout_s=0.3, max_attempts=10)
        c = coord.fleet.counts()
        if c["leases"] == 0 and c["queue"] == 0:
            break
        Worker(tmp_path / "fleet", worker_id=f"sweep-{time.monotonic_ns()}",
               tuners={"gemm": StubTuner()}, poll_s=0.01,
               heartbeat_s=0.05).run(max_jobs=8, idle_timeout_s=0.2)
    c = coord.fleet.counts()
    assert c["queue"] == 0 and c["leases"] == 0, c
    # the invariant: every published job reached done/failed EXACTLY once
    done = {p.stem for p in coord.fleet.done.glob("*.json")}
    failed = {p.stem for p in coord.fleet.failed.glob("*.json")}
    assert done | failed == {j.job_id for j in jobs}
    assert not (done & failed)
    # and the merge serves every done job's record despite torn shards
    coord.poll()
    merged = {tuple(sorted(r.inputs.items()))
              for r in store.records() if r.source == "fleet"}
    for j in jobs:
        if j.job_id in done:
            assert tuple(sorted(j.inputs.items())) in merged


# ---------------------------------------------------------------------------
# fsck CLI
# ---------------------------------------------------------------------------

def test_fsck_clean_store_exits_zero(tmp_path, capsys):
    path = tmp_path / "db.jsonl"
    RecordStore(path).add(_rec())
    assert tunedb_main(["fsck", str(path)]) == 0
    assert "verdict: OK" in capsys.readouterr().out


def test_fsck_detects_then_repairs_damage(tmp_path, capsys):
    path = tmp_path / "db.jsonl"
    s = RecordStore(path)
    s.add(_rec(0))
    with path.open("a") as fh:
        fh.write('{"bad\n')
    assert tunedb_main(["fsck", str(path)]) == 1
    with pytest.warns(RuntimeWarning):
        assert tunedb_main(["fsck", str(path), "--repair", "--json"]) == 0
    out = capsys.readouterr().out
    assert "repaired" in out
    assert tunedb_main(["fsck", str(path)]) == 0
    assert len(RecordStore.open(path)) == 1


def test_fsck_fleet_invariants(tmp_path, capsys):
    path = tmp_path / "db.jsonl"
    store = RecordStore(path)
    store.add(_rec())
    coord = Coordinator(tmp_path / "fleet", store)
    coord.publish([FleetJob(space="gemm", inputs=gemm_input(128, 64, 512))])
    fd = coord.fleet
    job, lp = fd.claim()
    fd.complete(job, lp, {"worker_id": "w"})
    # orphan lease behind the done marker + a garbage queue file
    (fd.leases / f"{job.job_id}.json").write_text(job.to_json())
    (fd.queue / "junk.json").write_text("not a job")
    args = ["fsck", str(path), "--fleet", str(tmp_path / "fleet")]
    assert tunedb_main(args) == 1
    assert tunedb_main(args + ["--repair"]) == 0
    assert tunedb_main(args) == 0
    capsys.readouterr()


# ---------------------------------------------------------------------------
# serving degradation: deadlines, shedding, /healthz, retune watchdog
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_model():
    import jax
    import jax.numpy as jnp
    from repro.models import ModelConfig, init_params
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_shed_threshold_rejects_newest_overflow(small_model):
    import numpy as np
    from repro.serve import Engine, ServeConfig
    cfg, params = small_model
    rng = np.random.default_rng(0)
    eng = Engine(cfg, params, ServeConfig(max_len=64, slots=2,
                                          shed_threshold=3))
    outs = eng.generate([rng.integers(0, 128, 5) for _ in range(6)],
                        max_new=4)
    assert eng.shed_requests == 3
    assert sum(1 for o in outs if not o) == 3
    # the OLDEST arrivals were served; the newest were shed
    assert all(len(o) == 4 for o in outs[:3])
    assert all(not o for o in outs[3:])
    assert not eng.shedding                   # backlog drained: healthy
    assert eng._health() is True


def test_request_deadline_rejects_and_retires(small_model):
    import numpy as np
    from repro.serve import Engine, ServeConfig
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, 5) for _ in range(3)]
    # an already-expired deadline: every request is rejected unserved
    eng = Engine(cfg, params, ServeConfig(max_len=64, slots=2,
                                          request_deadline_s=0.0))
    outs = eng.generate(prompts, max_new=4)
    assert all(not o for o in outs)
    assert eng.deadline_retired == 3
    # a generous deadline changes nothing
    eng2 = Engine(cfg, params, ServeConfig(max_len=64, slots=2,
                                           request_deadline_s=3600.0))
    ref = Engine(cfg, params, ServeConfig(max_len=64, slots=2))
    assert eng2.generate(prompts, max_new=4) == ref.generate(prompts,
                                                             max_new=4)
    assert eng2.deadline_retired == 0


def test_healthz_degrades_to_503():
    from repro.tunedb.obs import StatusServer
    state = {"ok": True}
    with StatusServer(port=0, health=lambda: (state["ok"], "shedding")) as s:
        assert urllib.request.urlopen(
            f"{s.url}/healthz", timeout=5).status == 200
        state["ok"] = False
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(f"{s.url}/healthz", timeout=5)
        assert exc.value.code == 503
        state["ok"] = True
        assert urllib.request.urlopen(
            f"{s.url}/healthz", timeout=5).status == 200


def test_retune_watchdog_cancels_hung_epoch(tmp_path):
    from repro.tunedb.controller import RetuneConfig, RetuneController
    store = RecordStore(tmp_path / "db.jsonl")
    ctl = RetuneController(store, async_mode=True,
                           cfg=RetuneConfig(session_window_s=0.1))
    # simulate a hung background epoch: alive thread, stale submit stamp
    release = threading.Event()
    th = threading.Thread(target=release.wait, daemon=True)
    th.start()
    ctl._async = th
    ctl.async_submit_t = time.perf_counter() - 1.0
    try:
        assert ctl.maybe_retune() is None
        assert ctl.watchdog_cancels == 1
        assert ctl._async_cancel.is_set()
        assert ctl.stats()["async"]["watchdog_cancels"] == 1
        # the cancel event short-circuits a fleet wait immediately
        coord = Coordinator(tmp_path / "fleet", store)
        coord.publish(
            [FleetJob(space="gemm", inputs=gemm_input(128, 64, 512))])
        t0 = time.perf_counter()
        assert coord.wait(timeout_s=30.0, poll_s=0.05,
                          cancel=ctl._async_cancel) is False
        assert time.perf_counter() - t0 < 5.0
        # second poll while still hung: no double count
        assert ctl.maybe_retune() is None
        assert ctl.watchdog_cancels == 1
    finally:
        release.set()
        th.join(timeout=5)
