"""tunedb fleet: lease protocol, coordinator/worker crash recovery, async
drift-triggered retunes, and the satellite fixes that ride along.

Pins the PR-4 contracts: a lease is claimed by exactly one racer (atomic
rename); a crashed worker's lease expires and its job is re-queued with no
duplicate serving commit; a restarted coordinator resumes the shard merge
from its cursors; ``RecordStore.merge`` preserves record provenance; the
retune controller budgets epochs (cooldown / sessions-per-window /
projected-gain floor); the model tier declines low-margin and off-manifold
resolutions; and an in-engine ASYNC retune triggered under synthetic drift
hot-swaps the serving state without blocking any decode tick (tick p99
within 2% of steady state).
"""

import json
import threading
import time

import numpy as np
import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.features import Featurizer
from repro.core.search import SearchResult, enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_generation,
                          install_serving, install_store, serving_state)
from repro.tunedb.controller import RetuneConfig, RetuneController
from repro.tunedb.fleet import (Coordinator, FleetJob, Worker,
                                run_fleet_inline)
from repro.tunedb.model import ModelSet, clear_models, get_models
from repro.tunedb.session import TuningSession, backend_fingerprint
from repro.tunedb.__main__ import main as tunedb_main

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


class StubTuner:
    """Deterministic, instant (or fixed-delay) tuner for fleet plumbing
    tests: the fleet is about coordination, not search quality."""

    def __init__(self, delay_s: float = 0.0, n_measured: int = 0,
                 fail: bool = False, fixed_cfg: bool = False):
        self.space = GEMM_SPACE
        self.backend = SimulatedTPUBackend(noise=0.0)
        self.delay_s = delay_s
        self.n_measured = n_measured     # extra top-k pairs -> sample records
        self.fail = fail
        # skip the pure-python legal-space enumeration (a GIL hog): the
        # timing tests need the background session to be sleep-shaped
        self.fixed_cfg = fixed_cfg
        self.calls = 0

    def search(self, inputs, remeasure=True):
        self.calls += 1
        if self.delay_s:
            time.sleep(self.delay_s)
        if self.fail:
            raise RuntimeError("synthetic tuner failure")
        if self.fixed_cfg:
            legal = [dict(CFG)]
        else:
            legal = enumerate_legal(self.space, inputs)
        cfg = legal[0]
        tf = float(self.backend.measure("gemm", cfg, inputs))
        measured = [(cfg, tf)]
        for extra in legal[1:1 + self.n_measured]:
            measured.append(
                (extra, float(self.backend.measure("gemm", extra, inputs))))
        return SearchResult(best=cfg, predicted_tflops=tf,
                            measured_tflops=tf, top_k=measured[:10],
                            n_candidates=len(legal), measured=measured)


def _shape(i: int):
    return gemm_input(256 * (i + 1), 64, 512)


def _fleet(tmp_path, **kw):
    store = RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store, **kw)
    return store, coord


# ---------------------------------------------------------------------------
# lease protocol
# ---------------------------------------------------------------------------

def test_publish_is_idempotent_across_lifecycle(tmp_path):
    _, coord = _fleet(tmp_path)
    job = FleetJob(space="gemm", inputs=_shape(0))
    assert coord.publish([job]) == 1
    assert coord.publish([job]) == 0               # queued: known
    fd = coord.fleet
    claimed = fd.claim()
    assert claimed is not None
    assert coord.publish([job]) == 0               # leased: known
    fd.complete(job, claimed[1], {"worker_id": "w"})
    assert coord.publish([job]) == 0               # done: never re-queued
    assert fd.counts() == {"queue": 0, "leases": 0, "done": 1, "failed": 0}
    # ... unless forced (the `fleet start --retune` path): the stale
    # terminal marker must not pin the shape forever
    assert coord.publish([job], force=True) == 1
    assert fd.counts() == {"queue": 1, "leases": 0, "done": 0, "failed": 0}
    assert coord.publish([job], force=True) == 0   # queued: still no dup


def test_publishing_revives_a_drained_fleet(tmp_path):
    """A directory that was drained once must serve later plans: publish
    clears the DRAIN marker, so new workers don't turn away at startup."""
    store, coord = _fleet(tmp_path)
    report = run_fleet_inline(            # run 1 ends with a DRAIN marker
        tmp_path / "fleet", store,
        [FleetJob(space="gemm", inputs=_shape(0))],
        n_workers=1, tuners={"gemm": StubTuner()})
    assert report.done == 1 and coord.fleet.draining()
    assert coord.publish([FleetJob(space="gemm", inputs=_shape(1))]) == 1
    assert not coord.fleet.draining()     # revived
    w = Worker(tmp_path / "fleet", worker_id="late",
               tuners={"gemm": StubTuner()}, poll_s=0.01)
    report2 = w.run(idle_timeout_s=0.5)   # does NOT exit before claiming
    assert report2.tuned == 1
    coord.poll()
    assert store.contains("gemm", _shape(1))


def test_stale_queue_wait_does_not_expire_a_fresh_claim(tmp_path):
    """A job that sat queued past the lease timeout must not be reclaimed
    the moment someone claims it: the claim freshens the mtime before the
    rename (which preserves mtime)."""
    _, coord = _fleet(tmp_path, lease_timeout_s=0.2)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    time.sleep(0.4)                       # queued longer than the timeout
    job, lease = coord.fleet.claim()
    assert coord.fleet.reclaim_expired(lease_timeout_s=0.2,
                                       max_attempts=3) == []
    assert coord.fleet.heartbeat(lease)   # still ours


def test_worker_started_before_the_bus_waits_then_attaches(tmp_path):
    """Workers may come up before any coordinator initialized the fleet
    dir: they idle (no crash) and bind once the manifest appears."""
    w = Worker(tmp_path / "fleet", worker_id="early",
               tuners={"gemm": StubTuner()}, poll_s=0.01)
    assert w.run_one() is None                     # no bus yet: just idle
    report = w.run(idle_timeout_s=0.05)
    assert report.claimed == 0
    store, coord = _fleet(tmp_path)                # the bus appears
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    assert w.run_one() is True
    coord.poll()
    assert store.contains("gemm", _shape(0))


def test_coordinator_refuses_mismatched_store(tmp_path):
    store, _ = _fleet(tmp_path)
    other = RecordStore.open(tmp_path / "other.jsonl")
    with pytest.raises(ValueError, match="was created for store"):
        Coordinator(tmp_path / "fleet", other)


def test_two_workers_racing_one_lease_single_winner(tmp_path):
    """The atomic-rename claim: over many rounds of two racers starting on a
    barrier, exactly one ever wins the single queued job."""
    _, coord = _fleet(tmp_path)
    fd = coord.fleet
    for i in range(20):
        job = FleetJob(space="gemm", inputs=_shape(i))
        assert coord.publish([job]) == 1
        barrier = threading.Barrier(2)
        wins = []

        def race():
            barrier.wait()
            got = fd.claim()
            if got is not None:
                wins.append(got)
        threads = [threading.Thread(target=race) for _ in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert len(wins) == 1, f"round {i}: {len(wins)} claim winners"
        fd.complete(wins[0][0], wins[0][1], {"worker_id": "racer"})


def test_heartbeat_keeps_lease_alive_expiry_requeues(tmp_path):
    _, coord = _fleet(tmp_path, lease_timeout_s=0.25)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    job, lease = coord.fleet.claim()
    time.sleep(0.15)
    assert coord.fleet.heartbeat(lease)            # refresh mtime
    time.sleep(0.15)
    # 0.3s since claim, 0.15s since the heartbeat: still alive
    assert coord.fleet.reclaim_expired(lease_timeout_s=0.25,
                                       max_attempts=3) == []
    time.sleep(0.3)                                # now it really expired
    assert coord.fleet.reclaim_expired(lease_timeout_s=0.25,
                                       max_attempts=3) == [job.job_id]
    assert not coord.fleet.heartbeat(lease)        # the zombie learns it lost
    requeued, _ = coord.fleet.claim()
    assert requeued.attempts == 1                  # the crash burned one


def test_expiry_exhausts_into_failed(tmp_path):
    _, coord = _fleet(tmp_path, lease_timeout_s=0.05, max_attempts=2)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    for _ in range(2):                             # claim, die, claim, die
        got = coord.fleet.claim()
        assert got is not None
        time.sleep(0.1)
        coord.poll()
    assert coord.fleet.counts()["failed"] == 1
    assert coord.fleet.claim() is None


# ---------------------------------------------------------------------------
# crash recovery
# ---------------------------------------------------------------------------

def test_worker_crash_requeues_without_duplicate_commits(tmp_path):
    """A worker dies mid-job: its lease expires, the job goes back to the
    queue, a healthy worker finishes it — and the parent store ends up with
    exactly ONE serving commit for the shape."""
    store, coord = _fleet(tmp_path, lease_timeout_s=0.1)
    inputs = _shape(3)
    coord.publish([FleetJob(space="gemm", inputs=inputs)])
    # worker 1 claims and dies: no heartbeat, no shard write, no marker
    assert coord.fleet.claim() is not None
    time.sleep(0.2)
    status = coord.poll()                          # expiry returns the job
    assert status["reclaimed"] != []
    w2 = Worker(tmp_path / "fleet", worker_id="w2",
                tuners={"gemm": StubTuner()}, poll_s=0.01)
    assert w2.run_one() is True
    assert w2.run_one() is None                    # queue is empty now
    coord.poll()
    assert store.contains("gemm", inputs)
    assert len(store.training_records()) == 1      # one commit, not two
    rec = store.get("gemm", inputs)
    assert rec.source == "fleet" and rec.merged_from == "w2"
    # repeated polls must not re-merge the shard (cursor holds)
    coord.poll()
    assert len(store.training_records()) == 1


def test_coordinator_restart_resumes_from_shard_state(tmp_path):
    store, coord = _fleet(tmp_path)
    jobs = [FleetJob(space="gemm", inputs=_shape(i)) for i in range(3)]
    coord.publish(jobs)
    w = Worker(tmp_path / "fleet", worker_id="w1",
               tuners={"gemm": StubTuner()}, poll_s=0.01)
    assert w.run_one() is True                     # one job done pre-crash
    coord.poll()
    assert len(store.training_records()) == 1

    # the coordinator "crashes"; a fresh one opens the same fleet dir
    coord2 = Coordinator(tmp_path / "fleet")
    assert coord2.store.path == store.path         # manifest remembers
    assert coord2.publish(jobs) == 0               # plan already in flight
    while w.run_one() is not None:
        pass
    coord2.poll()
    fresh = RecordStore.open(tmp_path / "db.jsonl")
    assert len(fresh) == 3
    # cursors survived the restart: the pre-crash record was not re-merged
    assert len(fresh.training_records()) == 3
    assert coord2.fleet.outstanding() == 0


def test_worker_job_failure_requeues_then_buries(tmp_path):
    store, coord = _fleet(tmp_path, max_attempts=2)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    bad = Worker(tmp_path / "fleet", worker_id="bad",
                 tuners={"gemm": StubTuner(fail=True)}, poll_s=0.01)
    assert bad.run_one() is False                  # attempt 1: requeued
    assert coord.fleet.counts()["queue"] == 1
    assert bad.run_one() is False                  # attempt 2: buried
    assert coord.fleet.counts()["failed"] == 1
    assert coord.outstanding() == 0
    assert len(store.training_records()) == 0


# ---------------------------------------------------------------------------
# inline fleet end-to-end + record equivalence
# ---------------------------------------------------------------------------

def test_fleet_matches_serial_session_records(tmp_path):
    """The distributed result must be indistinguishable from a serial
    session over the same plan: same serving records, same provenance-
    preserving log size."""
    shapes = [_shape(i) for i in range(6)]
    tuner = StubTuner(n_measured=3)

    serial_store = RecordStore.open(tmp_path / "serial.jsonl")
    session = TuningSession(tuner, serial_store, None, workers=1,
                            source="fleet")
    session.run(shapes=shapes)

    fleet_store = RecordStore.open(tmp_path / "db.jsonl")
    report = run_fleet_inline(
        tmp_path / "fleet", fleet_store,
        [FleetJob(space="gemm", inputs=s) for s in shapes],
        n_workers=3, tuners={"gemm": StubTuner(n_measured=3)})
    assert report.done == 6 and report.failed == 0
    assert report.merged_records == 6 and report.merged_samples == 6 * 3

    def view(store):
        return {(r.space, r.key, r.backend): (r.config, round(r.tflops, 9))
                for r in store.records()}
    assert view(fleet_store) == view(serial_store)
    assert len(fleet_store.training_records()) \
        == len(serial_store.training_records())


def test_merge_preserves_provenance(tmp_path):
    """The satellite bugfix: merging must not rewrite ``source`` (harvest
    and retune audits key on it); lineage lands in ``merged_from``."""
    src = RecordStore.open(tmp_path / "src.jsonl")
    src.add(TuneRecord(space="gemm", inputs=_shape(0), config=dict(CFG),
                       tflops=80.0, backend="bk", source="retune"))
    src.add(TuneRecord(space="gemm", inputs=_shape(0), config=dict(CFG),
                       tflops=1.0, backend="bk", source="sample"))
    dst = RecordStore()
    assert dst.merge(src) == 1                     # samples stay behind
    rec = dst.get("gemm", _shape(0))
    assert rec.source == "retune"                  # NOT rewritten to "merge"
    assert rec.merged_from == str(src.path)
    # explicit lineage label (the fleet's worker id) wins
    dst2 = RecordStore()
    dst2.merge(src, lineage="w7")
    assert dst2.get("gemm", _shape(0)).merged_from == "w7"
    # and the json round trip keeps it (old lines without it still load)
    line = rec.to_json()
    back = TuneRecord.from_json(line)
    assert back.merged_from == rec.merged_from
    assert TuneRecord.from_json(
        '{"space": "gemm", "inputs": {"M": 1}, "config": {}, '
        '"tflops": 1.0}').merged_from is None


# ---------------------------------------------------------------------------
# retune budget: cooldown, sessions-per-window, projected gain
# ---------------------------------------------------------------------------

def _drive_traffic(tel, inputs, n=40):
    for _ in range(n):
        tel.record("gemm", inputs)


def test_cooldown_ticks_blocks_back_to_back_epochs():
    store = RecordStore()
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": StubTuner()},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False, cooldown_ticks=100))
    _drive_traffic(tel, _shape(0))
    assert controller.maybe_retune(tick=10) is not None
    _drive_traffic(tel, _shape(1))                 # fresh drift right away
    assert controller.maybe_retune(tick=60) is None     # inside cooldown
    report = controller.maybe_retune(tick=120)     # cooldown over
    assert report is not None and report.tuned == 1


def test_session_budget_per_window():
    store = RecordStore()
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": StubTuner()},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False, max_sessions_per_window=1,
                         session_window_s=3600.0))
    _drive_traffic(tel, _shape(0))
    assert controller.maybe_retune() is not None
    _drive_traffic(tel, _shape(1))
    assert controller.maybe_retune() is None       # budget spent
    # the window rolls past: the same drift becomes actionable again
    controller._session_starts = [time.time() - 3601.0]
    assert controller.maybe_retune() is not None


class _StubPM:
    """resolve_model/predict_config stand-in for gain-projection tests."""

    def __init__(self, predicted):
        self.meta = {}
        self.predicted = predicted

    def predict_config(self, inputs, top_k=1):
        cfg = dict(CFG)
        return SearchResult(best=cfg, predicted_tflops=self.predicted,
                            measured_tflops=None, top_k=[(cfg, self.predicted)],
                            n_candidates=1)


class _StubModels:
    def __init__(self, predicted):
        self.pm = _StubPM(predicted)

    def resolve_model(self, space, backend=None):
        return self.pm


def test_min_gain_skips_low_upside_epochs():
    """An epoch whose model-projected win over the nearest record is below
    ``min_gain`` is skipped (debug log), not tuned."""
    store = RecordStore()
    near = _shape(0)
    store.add(TuneRecord(space="gemm", inputs=near, config=dict(CFG),
                         tflops=100.0, backend="bk"))
    install_serving(store=store, models=_StubModels(predicted=104.0))
    tel = get_telemetry()
    novel = gemm_input(288 * 1, 64, 512)           # a close, driftable shape
    controller = RetuneController(
        store, tuners={"gemm": StubTuner()},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False, min_gain=0.2))
    _drive_traffic(tel, novel)
    dec = controller.check()["gemm"]
    assert dec.projected_gain == pytest.approx(0.04)
    assert not dec.trigger                         # 4% < the 20% floor
    assert controller.maybe_retune() is None

    # a model that promises a real win clears the floor
    install_serving(models=_StubModels(predicted=150.0))
    dec = controller.check()["gemm"]
    assert dec.projected_gain == pytest.approx(0.5)
    assert dec.trigger
    assert controller.maybe_retune().tuned == 1


def test_min_gain_unprojectable_epoch_still_tunes():
    """No nearest record / no model => unbounded upside: never skipped."""
    store = RecordStore()
    install_store(store)                           # no models installed
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": StubTuner()},
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False, min_gain=0.5))
    _drive_traffic(tel, _shape(2))
    dec = controller.check()["gemm"]
    assert dec.trigger and dec.projected_gain is None
    assert controller.maybe_retune().tuned == 1


# ---------------------------------------------------------------------------
# model-tier confidence gating
# ---------------------------------------------------------------------------

def _fitted_featurizer(shapes):
    f = Featurizer(space=GEMM_SPACE)
    f.fit(f.raw_batch([(s, dict(CFG)) for s in shapes]))
    return f


class _GatePM:
    """A PerfModel stand-in with controllable top-2 predictions."""

    def __init__(self, featurizer, top):
        self.meta = {}
        self.featurizer = featurizer
        self.top = top

    def predict_config(self, inputs, top_k=1):
        return SearchResult(best=self.top[0][0],
                            predicted_tflops=self.top[0][1],
                            measured_tflops=None, top_k=self.top[:top_k],
                            n_candidates=len(self.top))


def _gate_models(margin, max_z, top):
    shapes = [gemm_input(256 * (i + 1), 64, 512) for i in range(4)]
    ms = ModelSet(margin_threshold=margin, max_feature_z=max_z)
    ms.models[("gemm", "bk")] = _GatePM(_fitted_featurizer(shapes), top)
    return ms


def test_margin_gate_declines_ambivalent_argmax():
    top = [(dict(CFG), 100.0), (dict(CFG, bm=128), 99.9)]
    gated = _gate_models(0.05, 0.0, top)
    assert gated.predict("gemm", _shape(1)) is None
    assert gated.gated == 1 and gated.misses == 1
    # same prediction, gate off: the argmax serves
    open_ms = _gate_models(0.0, 0.0, top)
    assert open_ms.predict("gemm", _shape(1)) == (CFG, 100.0)
    # a decisive margin passes the gate
    decisive = _gate_models(0.05, 0.0, [(dict(CFG), 100.0),
                                        (dict(CFG, bm=128), 80.0)])
    assert decisive.predict("gemm", _shape(1)) == (CFG, 100.0)
    assert decisive.gated == 0


def test_off_manifold_gate_z_score():
    top = [(dict(CFG), 100.0)]
    ms = _gate_models(0.0, 4.0, top)
    # a shape inside the training range serves
    assert ms.predict("gemm", _shape(2)) is not None
    # M six orders of magnitude off the manifold: decline, fall through
    far = gemm_input(1 << 22, 64, 512)
    assert ms.predict("gemm", far) is None
    assert ms.gated == 1
    # the decline is memoized like any other resolution
    assert ms.predict("gemm", far) is None
    assert ms.gated == 1


def test_gating_is_serving_policy_across_retrain_swap():
    ms = ModelSet(margin_threshold=0.07, max_feature_z=3.5)
    out = ms.merged_with(ModelSet())               # freshly trained defaults
    assert out.margin_threshold == 0.07
    assert out.max_feature_z == 3.5
    assert json.dumps(ms.stats())                  # gated counter serializes


def test_dispatch_falls_to_nearest_when_model_gated():
    """The three-tier contract under gating: a declined model resolution
    serves the nearest record, not the (possibly wrong) model argmax."""
    store = RecordStore()
    near_cfg = dict(CFG, bm=128)
    store.add(TuneRecord(space="gemm", inputs=gemm_input(1 << 21, 64, 512),
                         config=near_cfg, tflops=90.0, backend="bk"))
    wrong_cfg = dict(CFG, bm=8)
    ms = _gate_models(0.0, 4.0, [(wrong_cfg, 999.0)])
    install_serving(store=store, models=ms)
    probe = gemm_input(1 << 22, 64, 512)           # off the model's manifold
    cfg = dispatch._tuned_cfg("gemm", probe)
    assert cfg == near_cfg                         # tier 3 won, not the model
    assert ms.gated == 1


# ---------------------------------------------------------------------------
# async retunes: controller level
# ---------------------------------------------------------------------------

def test_async_submit_reap_cycle():
    store = RecordStore()
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": StubTuner(delay_s=0.3)}, async_mode=True,
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=False))
    _drive_traffic(tel, _shape(0))
    gen0 = install_generation()
    assert controller.maybe_retune(tick=0) is None      # submit, not block
    assert controller.async_active()
    assert controller.maybe_retune(tick=16) is None     # in flight: skipped
    deadline = time.time() + 10
    report = None
    while report is None and time.time() < deadline:
        time.sleep(0.05)
        report = controller.maybe_retune(tick=32)       # eventually reaps
    assert report is not None and report.mode == "async"
    assert report.tuned == 1 and controller.retunes == 1
    assert install_generation() > gen0                  # the swap landed
    assert controller.maybe_retune(tick=48) is None     # reaped exactly once


def test_async_retrain_completes_store_and_model_swap():
    """The full async epoch: session samples -> regressor retrain -> ONE
    generation flip publishing store AND models together."""
    store = RecordStore()
    install_serving(store=store, models=None)
    tel = get_telemetry()
    controller = RetuneController(
        store, tuners={"gemm": StubTuner(n_measured=40)}, async_mode=True,
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, workers=1,
                         retrain=True, min_train_samples=10, train_epochs=2))
    _drive_traffic(tel, _shape(0))
    gen0 = install_generation()
    assert controller.maybe_retune() is None
    report = controller.wait_async(timeout=60)
    assert report is not None and report.tuned == 1
    fp = backend_fingerprint(SimulatedTPUBackend(noise=0.0))
    assert report.retrained == [f"gemm/{fp}"]
    assert install_generation() == gen0 + 1             # ONE atomic flip
    assert serving_state().store is store
    assert len(get_models()) == 1


def test_fleet_retune_swaps_only_after_merge(tmp_path):
    """Fleet-routed async epoch: the swap must not happen before the
    coordinator merged the worker's shard into the serving store."""
    store = RecordStore.open(tmp_path / "db.jsonl")
    install_store(store)
    tel = get_telemetry()
    fleet_dir = tmp_path / "fleet"
    controller = RetuneController(
        store, fleet_dir=fleet_dir, fleet_poll_s=0.02, fleet_timeout_s=30,
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, retrain=False))
    _drive_traffic(tel, _shape(0))
    gen0 = install_generation()
    assert controller.maybe_retune() is None
    # no worker yet: the epoch stays in flight, no swap
    deadline = time.time() + 5
    while not (fleet_dir / "manifest.json").exists() \
            and time.time() < deadline:
        time.sleep(0.02)
    time.sleep(0.2)
    assert controller.async_active() and install_generation() == gen0
    worker = Worker(fleet_dir, worker_id="w1",
                    tuners={"gemm": StubTuner()}, poll_s=0.01)
    worker.run(idle_timeout_s=1.0)
    report = controller.wait_async(timeout=30)
    assert report is not None and report.mode == "fleet"
    assert report.tuned == 1
    assert install_generation() == gen0 + 1
    rec = store.get("gemm", _shape(0))
    assert rec.source == "retune" and rec.merged_from == "w1"
    assert (fleet_dir / "report.json").exists()


def test_fleet_retune_needs_disk_backed_store():
    store = RecordStore()                          # in-memory: no shards
    install_store(store)
    tel = get_telemetry()
    controller = RetuneController(
        store, fleet_dir="/nonexistent-fleet",
        cfg=RetuneConfig(min_calls=8, top_k_shapes=1, retrain=False),
        tuners={"gemm": StubTuner()})
    _drive_traffic(tel, _shape(0))
    with pytest.warns(RuntimeWarning, match="disk-backed"):
        controller.maybe_retune()
    report = controller.wait_async(timeout=30)     # in-process fallback ran
    assert report is not None and report.tuned == 1


# ---------------------------------------------------------------------------
# the acceptance loop: in-engine async retune never stalls a decode tick
# ---------------------------------------------------------------------------

def _rolling_median(xs, w=5):
    """De-spike a tick-time series: isolated OS-scheduler/GC hiccups (which
    hit steady and in-flight windows alike) must not decide the comparison,
    while anything sustained — a tick genuinely waiting on session work —
    survives the filter."""
    xs = np.asarray(xs)
    k = w // 2
    return np.array([np.median(xs[max(0, i - k):i + k + 1])
                     for i in range(len(xs))])


def test_engine_async_retune_keeps_tick_p99_flat():
    """The acceptance loop: synthetic drift triggers an ASYNC retune
    mid-generate; the epoch — deliberately slowed to span hundreds of
    ticks — completes a hot-swap while decode ticks keep flowing.

    Two classes of assertion:
      * deterministic (every attempt): serving never pauses, exactly one
        epoch is submitted, the swap lands, and NO tick comes anywhere
        near the session length — the inline controller would block one
        tick for the full 0.8s session.
      * statistical: the p99 decode tick during the in-flight session
        stays within 2% of the steady-state p99 (rolling-median smoothed,
        GC parked).  Shared CI boxes occasionally inject >2% of ambient
        scheduler noise into one window, so this check may retry on a
        fresh engine; a real regression fails every attempt.
    """
    import gc

    import jax
    import jax.numpy as jnp

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))

    ratios = []
    for attempt in range(3):
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        slow = StubTuner(delay_s=0.8, fixed_cfg=True)   # ticks are ~2ms: the
        engine = Engine(                                # session spans 100s
            cfg, params,                                # of ticks
            ServeConfig(max_len=2048, slots=2, retune=True,
                        retune_async=True, retune_interval=256,
                        retune_min_calls=8, retune_top_k=2,
                        retune_train=False, record_tick_times=True,
                        retune_cooldown_ticks=100_000),  # exactly one epoch
            retune_tuners={"gemm": slow})
        controller = engine.controller
        assert controller is not None and controller.async_mode

        # warm the jit caches so compile never pollutes the timing window
        engine.generate([np.arange(4), np.arange(6)], max_new=8)
        engine.tick_times.clear()
        controller.reset_baseline()
        # synthetic drift: novel hot shapes the store has never seen
        tel = get_telemetry()
        for i in range(3):
            _drive_traffic(tel, gemm_input(384 * (i + 1), 48, 768), n=80)

        gen0 = install_generation()
        gc.disable()                    # GC pauses are ambient, not retune
        try:
            outs = engine.generate([np.arange(4), np.arange(6)], max_new=900)
        finally:
            gc.enable()
        assert all(len(o) == 900 for o in outs)    # serving never stopped
        report = controller.wait_async(timeout=60)
        if report is None:                         # reaped in-loop already
            report = controller.last_report
        assert controller.async_submits == 1
        assert report is not None and report.tuned >= 1
        assert install_generation() > gen0         # the hot-swap landed
        assert len(controller.store.records()) >= 1
        assert all(r.source == "retune"
                   for r in controller.store.records())

        t_submit, t_done = controller.async_submit_t, controller.async_done_t
        assert t_submit is not None and t_done is not None
        steady = [w for t0, w, _ in engine.tick_times[5:]
                  if t0 + w < t_submit]
        inflight = [w for t0, w, _ in engine.tick_times
                    if t_submit <= t0 <= t_done]
        assert len(steady) >= 100 and len(inflight) >= 100, \
            (len(steady), len(inflight))
        # Inline execution would park the polling tick for the whole ~0.8s
        # epoch — a tick anywhere near the session length fails hard.
        # Smaller ambient scheduler stalls (tens to a couple hundred ms on
        # a shared box) go through the retry with the p99 check instead.
        assert max(inflight) < slow.delay_s

        p99_steady = float(np.percentile(_rolling_median(steady), 99))
        p99_inflight = float(np.percentile(_rolling_median(inflight), 99))
        ratios.append((p99_inflight / p99_steady, max(inflight)))
        if ratios[-1][0] <= 1.02 and ratios[-1][1] < slow.delay_s / 4:
            break
    assert any(r <= 1.02 and m < slow.delay_s / 4 for r, m in ratios), \
        f"in-flight ticks stayed degraded across attempts: {ratios}"


# ---------------------------------------------------------------------------
# CLI: fleet start / worker / status / drain
# ---------------------------------------------------------------------------

def test_cli_fleet_round_trip(tmp_path, capsys):
    db = tmp_path / "db.jsonl"
    fleet = tmp_path / "fleet"
    rc = tunedb_main([
        "fleet", "start", "--fleet", str(fleet), "--store", str(db),
        "--space", "gemm", "--shape", "M=512,N=128,K=512", "--drain"])
    assert rc == 0
    assert "published 1 job(s)" in capsys.readouterr().out

    rc = tunedb_main(["fleet", "status", "--fleet", str(fleet)])
    assert rc == 0
    status = json.loads(capsys.readouterr().out)
    assert status["counts"]["queue"] == 1 and status["draining"]

    rc = tunedb_main([
        "fleet", "worker", "--fleet", str(fleet), "--worker-id", "cli-w",
        "--train-samples", "400", "--epochs", "2", "--no-remeasure"])
    assert rc == 0
    assert "1 tuned" in capsys.readouterr().out

    rc = tunedb_main(["fleet", "drain", "--fleet", str(fleet), "--wait",
                      "--timeout", "30"])
    assert rc == 0
    out = capsys.readouterr().out
    report = json.loads((fleet / "report.json").read_text())
    assert report["done"] == 1 and report["failed"] == 0
    assert report["workers"] == ["cli-w"]
    store = RecordStore.open(db)
    assert store.contains("gemm", gemm_input(512, 128, 512))
    assert store.get("gemm", gemm_input(512, 128, 512)).merged_from == "cli-w"
    assert "\"done\": 1" in out


def test_cli_fleet_status_rejects_non_fleet_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        tunedb_main(["fleet", "status", "--fleet", str(tmp_path / "nope")])


# ---------------------------------------------------------------------------
# PR 5 satellites: priority claiming, shard GC, --workers spawner
# ---------------------------------------------------------------------------

def test_workers_claim_hottest_jobs_first(tmp_path):
    _, coord = _fleet(tmp_path)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0), count=1),
                   FleetJob(space="gemm", inputs=_shape(1), count=50),
                   FleetJob(space="gemm", inputs=_shape(2), count=5)])
    order = []
    for _ in range(3):
        job, lease = coord.fleet.claim()
        order.append(job.count)
        lease.unlink()
    assert order == [50, 5, 1]           # hottest telemetry count first
    assert coord.fleet.claim() is None


def test_requeued_job_keeps_its_priority(tmp_path):
    _, coord = _fleet(tmp_path)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0), count=7)])
    job, lease = coord.fleet.claim()
    coord.fleet.fail(job, lease, "synthetic", max_attempts=3)
    job2, lease2 = coord.fleet.claim()
    assert job2.count == 7 and job2.attempts == 1
    lease2.unlink()


def test_claim_priority_updates_on_republication(tmp_path):
    """A republished job (retune of a completed shape) with a hotter count
    must not be ordered by its stale cached priority."""
    _, coord = _fleet(tmp_path)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0), count=5),
                   FleetJob(space="gemm", inputs=_shape(1), count=10)])
    job, lease = coord.fleet.claim()     # caches shape(1) at count=10
    assert job.count == 10
    coord.fleet.complete(job, lease, {})
    assert coord.publish([FleetJob(space="gemm", inputs=_shape(1),
                                   count=500)], force=True) == 1
    job2, lease2 = coord.fleet.claim()
    assert job2.count == 500             # fresh file invalidated the cache
    lease2.unlink()


def test_retune_fleet_jobs_carry_telemetry_counts(tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    controller = RetuneController(
        store, tuners={"gemm": StubTuner(fixed_cfg=True)},
        fleet_dir=tmp_path / "fleet", fleet_timeout_s=0.2, fleet_poll_s=0.02,
        cfg=RetuneConfig(min_calls=8, top_k_shapes=2))
    _drive_traffic(get_telemetry(), _shape(0), n=40)
    controller.maybe_retune()            # submits; no workers: will time out
    assert controller.wait_async(timeout=30.0) is not None
    jobs = sorted((tmp_path / "fleet" / "queue").glob("*.json"))
    assert jobs, "the drift-triggered plan published nothing"
    published = [json.loads(p.read_text()) for p in jobs]
    assert any(j["count"] == 40 for j in published)


def test_drain_compact_archives_cursor_complete_shards(tmp_path):
    store, coord = _fleet(tmp_path)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0)),
                   FleetJob(space="gemm", inputs=_shape(1))])
    worker = Worker(tmp_path / "fleet", worker_id="w0",
                    tuners={"gemm": StubTuner(n_measured=2)})
    assert worker.run_one() and worker.run_one()
    coord.poll()                         # merge both records
    assert len(store) == 2
    shard_dir = coord.fleet.shard_dir()
    assert list(shard_dir.glob("*.jsonl"))

    archived = coord.compact_shards()
    assert archived == ["w0"]
    assert not list(shard_dir.glob("*.jsonl"))
    assert (shard_dir / "archive" / "w0.jsonl").exists()
    assert not (tmp_path / "fleet" / "merged" / "w0.json").exists()

    # a returning worker with the SAME id starts a fresh shard; the reset
    # cursor merges it from byte 0 — nothing skipped, nothing duplicated
    coord.publish([FleetJob(space="gemm", inputs=_shape(2))])
    worker2 = Worker(tmp_path / "fleet", worker_id="w0",
                     tuners={"gemm": StubTuner()})
    assert worker2.run_one()
    coord.poll()
    assert store.contains("gemm", _shape(2)) and len(store) == 3


def test_compact_skips_shards_with_unmerged_bytes(tmp_path):
    store, coord = _fleet(tmp_path)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    worker = Worker(tmp_path / "fleet", worker_id="w0",
                    tuners={"gemm": StubTuner()})
    assert worker.run_one()
    assert coord.compact_shards() == []  # nothing merged yet: must stay
    coord.poll()
    assert coord.compact_shards() == ["w0"]
    assert len(store) == 1


def test_cli_drain_compact(tmp_path, capsys):
    db, fleet = tmp_path / "db.jsonl", tmp_path / "fleet"
    store = RecordStore.open(db)
    coord = Coordinator(fleet, store)
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    worker = Worker(fleet, worker_id="w0", tuners={"gemm": StubTuner()})
    assert worker.run_one()
    rc = tunedb_main(["fleet", "drain", "--fleet", str(fleet), "--wait",
                      "--timeout", "30", "--compact"])
    assert rc == 0
    assert "compacted 1 merged shard(s)" in capsys.readouterr().out
    shard_dir = coord.fleet.shard_dir()
    assert not list(shard_dir.glob("*.jsonl"))
    assert (shard_dir / "archive" / "w0.jsonl").exists()
    assert RecordStore.open(db).contains("gemm", _shape(0))


def test_cli_drain_compact_without_wait(tmp_path, capsys):
    """--compact must act (merge what landed, then archive) even without
    --wait — never a silent no-op."""
    db, fleet = tmp_path / "db.jsonl", tmp_path / "fleet"
    coord = Coordinator(fleet, RecordStore.open(db))
    coord.publish([FleetJob(space="gemm", inputs=_shape(0))])
    worker = Worker(fleet, worker_id="w0", tuners={"gemm": StubTuner()})
    assert worker.run_one()
    rc = tunedb_main(["fleet", "drain", "--fleet", str(fleet), "--compact"])
    assert rc == 0
    assert "compacted 1 merged shard(s)" in capsys.readouterr().out
    assert not list(coord.fleet.shard_dir().glob("*.jsonl"))
    assert RecordStore.open(db).contains("gemm", _shape(0))


def test_fleet_start_spawns_local_workers(tmp_path, monkeypatch, capsys):
    """--workers N forks N `fleet worker` subprocesses against the bus,
    implies drain+wait, and reaps the children before returning."""
    import subprocess

    spawned = []

    class _FakeProc:
        def __init__(self, cmd):
            self.cmd = cmd
            self.pid = 4000 + len(spawned)

        def wait(self, timeout=None):
            return 0

        def terminate(self):
            raise AssertionError("healthy fake workers are never terminated")

    def fake_popen(cmd, **kw):
        proc = _FakeProc(cmd)
        spawned.append(proc)
        return proc

    monkeypatch.setattr(subprocess, "Popen", fake_popen)
    db, fleet = tmp_path / "db.jsonl", tmp_path / "fleet"
    rc = tunedb_main(["fleet", "start", "--fleet", str(fleet),
                      "--store", str(db), "--workers", "2",
                      "--worker-train-samples", "300", "--worker-epochs", "2",
                      "--timeout", "5"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "spawned 2 local worker process(es)" in out
    assert len(spawned) == 2
    for proc in spawned:
        assert proc.cmd[1:4] == ["-m", "repro.tunedb", "fleet"]
        assert "worker" in proc.cmd
        assert str(fleet) in proc.cmd
        assert "300" in proc.cmd and "2" in proc.cmd
    # one-command mode marks the plan final so the workers exit on empty
    from repro.tunedb.fleet import FleetDir
    assert FleetDir(fleet).draining()
