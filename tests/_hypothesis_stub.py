"""Minimal deterministic stand-in for `hypothesis` (not installed here).

The container bakes its dependency set; when the real `hypothesis` is absent
conftest.py installs this module in its place so the property tests still
run.  Semantics are reduced but honest: each `@given` test runs
``max_examples`` deterministic pseudo-random samples drawn from the declared
strategies (seeded per test name), so failures are reproducible.  Only the
strategy surface this repo's tests use is provided: ``integers``,
``floats``, ``sampled_from``, and ``.filter``.
"""

from __future__ import annotations

import functools
import hashlib
import inspect
from typing import Any, Callable, Sequence

import numpy as np


class _Strategy:
    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def filter(self, pred: Callable[[Any], bool]) -> "_Strategy":
        def draw(rng: np.random.Generator) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter predicate rejected 1000 samples")
        return _Strategy(draw)


class strategies:
    @staticmethod
    def integers(lo: int, hi: int) -> _Strategy:
        return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))

    @staticmethod
    def floats(lo: float, hi: float) -> _Strategy:
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)))

    @staticmethod
    def sampled_from(seq: Sequence[Any]) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rng: items[int(rng.integers(len(items)))])


def settings(max_examples: int = 10, deadline: Any = None, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        n = getattr(fn, "_stub_settings", {}).get("max_examples", 10)
        seed = int(hashlib.sha256(fn.__name__.encode()).hexdigest()[:8], 16)

        # strategies fill the RIGHTMOST params (hypothesis semantics); only
        # the leading ones are pytest fixtures — hide the rest from pytest.
        # Drawn values are passed by NAME because pytest passes fixtures as
        # keyword arguments.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        strat_names = [p.name for p in params[len(params) - len(strats):]]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {name: s._draw(rng)
                         for name, s in zip(strat_names, strats)}
                fn(*args, **kwargs, **drawn)

        wrapper.__signature__ = sig.replace(
            parameters=params[:len(params) - len(strats)])
        return wrapper
    return deco


st = strategies
