"""Parameter-space legality (paper §4: X vs X-hat)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.space import (CONV_SPACE, GEMM_SPACE, SPACES, gemm_input,
                              conv_input, gemm_vmem_bytes, VMEM_USABLE)


def test_cardinality():
    assert GEMM_SPACE.cardinality() == np.prod(
        [len(v) for v in GEMM_SPACE.params.values()])
    assert GEMM_SPACE.cardinality() > 10_000      # a real search space


def test_enumerate_legal_nonempty_for_practical_inputs():
    for m, n, k in [(512, 512, 512), (2560, 16, 2560), (32, 32, 60000),
                    (4096, 4096, 32)]:
        legal = GEMM_SPACE.enumerate_legal(gemm_input(m, n, k))
        assert legal, (m, n, k)


def test_legal_subset_of_possible():
    inputs = gemm_input(256, 256, 4096)
    legal = GEMM_SPACE.enumerate_legal(inputs)
    for cfg in legal[:50]:
        assert GEMM_SPACE.contains(cfg)
        assert gemm_vmem_bytes(cfg, 16) <= VMEM_USABLE


@given(st.sampled_from([16, 32]),
       st.integers(5, 13), st.integers(4, 11), st.integers(5, 14))
@settings(max_examples=30, deadline=None)
def test_legality_invariants(bits, lm, ln, lk):
    """Property: every config accepted by is_legal respects VMEM, alignment
    and split bounds (the definition of X)."""
    inputs = gemm_input(2 ** lm, 2 ** ln, 2 ** lk, dtype_bits=bits)
    rng = np.random.default_rng(lm * 100 + ln * 10 + lk)
    names = GEMM_SPACE.param_names
    for _ in range(20):
        cfg = {n: int(rng.choice(GEMM_SPACE.params[n])) for n in names}
        if GEMM_SPACE.is_legal(cfg, inputs):
            assert gemm_vmem_bytes(cfg, bits) <= VMEM_USABLE
            assert cfg["bm"] % 8 == 0 and cfg["bn"] % 128 == 0
            k_steps = -(-inputs["K"] // cfg["bk"])
            assert cfg["k_split"] <= k_steps
            if bits == 32:
                assert cfg["acc32"] == 1


def test_conv_legal():
    inputs = conv_input(16, 24, 240, 32, 32, 3, 3)
    legal = CONV_SPACE.enumerate_legal(inputs)
    assert legal
    for cfg in legal[:20]:
        assert cfg["rs_unroll"] <= 9


def test_all_spaces_registered():
    assert set(SPACES) == {"gemm", "conv", "attention", "ssd"}
    for sp in SPACES.values():
        assert sp.cardinality() > 0 and sp.input_params
