"""Fault-tolerance machinery."""

import signal
import time

from repro.train import PreemptionHandler, StragglerMonitor


def test_straggler_detection():
    mon = StragglerMonitor(threshold=3.0, grace_steps=2)
    events = []
    mon.on_straggler = lambda s, dt, base: events.append(s)
    # healthy steps establish a baseline
    for i in range(5):
        mon.step_start()
        time.sleep(0.01)
        mon.step_end(i)
    # one straggler
    mon.step_start()
    time.sleep(0.08)
    mon.step_end(5)
    assert events == [5]
    # baseline not poisoned: a healthy step after is NOT flagged
    mon.step_start()
    time.sleep(0.01)
    mon.step_end(6)
    assert events == [5]


def test_preemption_flag():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.should_stop
    signal.raise_signal(signal.SIGUSR1)
    assert h.should_stop
    h.restore()
