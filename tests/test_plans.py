"""Golden plan artifacts + plan-following fleet (PR 7).

Pins the tentpole contracts: an exported ``DispatchPlan`` artifact
round-trips into a fresh process byte-verified and table-identical
(overlay promotions frozen in); every corruption mode — tampered entries,
torn manifest, future schema, stale store — is REFUSED, never partially
served; the registry's publish/follow protocol hot-swaps whole
generations only (no torn plan, no generation rollback), with the
regression sentry gating coverage loss; and the serving/CLI/observability
surfaces (``install_serving(plan_dir=)``, ``ServeConfig``, ``tunedb
plan``, ``/status``, ``/metrics``) all agree on what is installed.
"""

import json
import subprocess
import sys
import threading
from pathlib import Path

import pytest

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.tunedb import (DispatchPlan, RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_serving,
                          serving_state, shape_key)
from repro.tunedb.model import clear_models
from repro.tunedb.obs import RegressionSentry, status_snapshot
from repro.tunedb.obs.metrics import get_registry, reset_metrics
from repro.tunedb.plans import (ENTRIES_NAME, MANIFEST_NAME,
                                PLAN_SCHEMA_VERSION, PlanArtifactError,
                                PlanFollower, PlanRegistry, StalePlanError,
                                check_freshness, default_plan_dir,
                                export_plan, load_plan, read_manifest)
from repro.tunedb.plans import _FOLLOWERS, _FOLLOWERS_LOCK

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
        reset_metrics()
        with _FOLLOWERS_LOCK:
            for f in list(_FOLLOWERS):
                f._stop.set()
            _FOLLOWERS.clear()
    reset()
    yield
    reset()


def _rec(m, n, k, *, backend="test", tflops=100.0, **cfg_over):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k),
                      config=dict(CFG, **cfg_over), tflops=tflops,
                      backend=backend)


def _seed_store(path, n=4):
    store = RecordStore(path)
    for i in range(n):
        store.add(_rec(256 * (i + 1), 64, 1024, bm=64 * (1 + i % 2)))
    return store


def _compiled_plan(store):
    install_serving(store=store)
    plan = serving_state().plan
    assert plan is not None and plan.source == "compiled"
    return plan


# ---------------------------------------------------------------------------
# artifact round trip
# ---------------------------------------------------------------------------

def test_export_load_round_trip_table_identical(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    plan = _compiled_plan(store)
    # a slow-path promotion must be frozen into the artifact too
    promoted = gemm_input(640, 64, 1024)
    plan.promote("gemm", shape_key(promoted), dict(CFG, bm=32), "nearest")

    dest = export_plan(plan, default_plan_dir(store.path), store=store)
    assert dest == tmp_path / "s.jsonl.plan" / "00000001"
    loaded = load_plan(dest)

    assert loaded.source == "loaded"
    assert loaded.digest == read_manifest(dest).digest
    assert len(loaded) == len(plan)
    for i in range(4):
        key = shape_key(gemm_input(256 * (i + 1), 64, 1024))
        assert loaded.lookup("gemm", key) == plan.lookup("gemm", key)
    # the promoted overlay entry is a base-table entry after the round trip
    assert loaded.lookup("gemm", shape_key(promoted)) == \
        (dict(CFG, bm=32), "nearest")


def test_export_refuses_when_store_outran_the_plan(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    plan = _compiled_plan(store)
    store.add(_rec(4096, 64, 1024))         # store advances past the compile
    with pytest.raises(StalePlanError, match="recompile"):
        export_plan(plan, tmp_path / "out", store=store)
    # refusal is whole: no partial artifact directory appeared
    assert not any((tmp_path / "out").glob("*")) \
        or not (tmp_path / "out").exists()
    # recompiling clears the gate
    plan2 = _compiled_plan(store)
    assert export_plan(plan2, tmp_path / "out", store=store).exists()


def test_load_refuses_tampered_entries(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    blob = (dest / ENTRIES_NAME).read_bytes()
    (dest / ENTRIES_NAME).write_bytes(blob.replace(b'"bm": 64', b'"bm": 8'))
    with pytest.raises(PlanArtifactError, match="digest mismatch"):
        load_plan(dest)


def test_load_refuses_torn_or_missing_manifest(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    manifest = (dest / MANIFEST_NAME).read_text()
    (dest / MANIFEST_NAME).write_text(manifest[:len(manifest) // 2])
    with pytest.raises(PlanArtifactError, match="torn or unreadable"):
        load_plan(dest)
    (dest / MANIFEST_NAME).unlink()
    with pytest.raises(PlanArtifactError, match="no manifest"):
        read_manifest(dest)


def test_load_refuses_future_schema(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    doc = json.loads((dest / MANIFEST_NAME).read_text())
    doc["plan_schema_version"] = PLAN_SCHEMA_VERSION + 1
    (dest / MANIFEST_NAME).write_text(json.dumps(doc))
    with pytest.raises(PlanArtifactError, match="refusing to misread"):
        load_plan(dest)


def test_load_refuses_entry_count_drift(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    doc = json.loads((dest / MANIFEST_NAME).read_text())
    doc["n_entries"] = doc["n_entries"] + 1
    (dest / MANIFEST_NAME).write_text(json.dumps(doc))
    with pytest.raises(PlanArtifactError, match="promises"):
        load_plan(dest)


def test_freshness_warns_when_store_gained_records_since_export(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    assert check_freshness(read_manifest(dest), store) is None
    store.add(TuneRecord(space="gemm", inputs=gemm_input(4096, 64, 1024),
                         config=CFG, tflops=50.0, created_at=9e9))
    warning = check_freshness(read_manifest(dest), store)
    assert warning is not None and "newer" in warning


# ---------------------------------------------------------------------------
# cold install: plan_dir skips the install-time scans
# ---------------------------------------------------------------------------

class _CountingModels:
    """A ModelSet stand-in that fails the test if install consults it."""

    def __init__(self):
        self.calls = 0

    def predict(self, *a, **k):
        self.calls += 1
        return None

    def __len__(self):
        return 1


def test_install_plan_dir_cold_start_skips_model_scans(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    tel = get_telemetry()
    for i in range(4):
        tel.record("gemm", gemm_input(256 * (i + 1), 64, 1024), n=5)
    plan = _compiled_plan(store)
    warm_cfg = dispatch._tuned_cfg("gemm", gemm_input(256, 64, 1024))
    dest = export_plan(plan, tmp_path / "out", store=store)

    # fresh handles, as a cold process would open them
    clear_store()
    clear_telemetry()
    cold_store = RecordStore.open(tmp_path / "s.jsonl")
    models = _CountingModels()
    state = install_serving(store=cold_store, models=models, plan_dir=dest)
    assert state.plan.source == "loaded"
    assert state.plan.digest == read_manifest(dest).digest
    assert models.calls == 0            # the whole point of the artifact
    assert dispatch._tuned_cfg("gemm", gemm_input(256, 64, 1024)) == warm_cfg


def test_install_plan_only_serving_no_store(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    clear_store()
    state = install_serving(plan_dir=dest)
    # fingerprint adopted from the artifact, resolution works store-less
    assert state.plan.source == "loaded"
    cfg = dispatch._tuned_cfg("gemm", gemm_input(256, 64, 1024))
    assert cfg is not None and cfg["bm"] == CFG["bm"]
    assert state.plan.hits >= 1


def test_install_bad_plan_dir_raises_not_degrades(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    with pytest.raises(PlanArtifactError):
        install_serving(store=store, plan_dir=tmp_path / "nope")


def test_fresh_process_installs_from_artifact(tmp_path):
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    code = (
        "from repro.tunedb import RecordStore, install_serving, "
        "serving_state, shape_key\n"
        "from repro.core.space import gemm_input\n"
        f"store = RecordStore.open({str(tmp_path / 's.jsonl')!r})\n"
        f"state = install_serving(store=store, plan_dir={str(dest)!r})\n"
        "assert state.plan.source == 'loaded', state.plan.source\n"
        "entry = state.plan.lookup('gemm', "
        "shape_key(gemm_input(256, 64, 1024)))\n"
        "assert entry is not None and entry[1] == 'exact'\n"
        "print('cold-ok', state.plan.stats()['entries'])\n")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": str(Path(__file__).resolve().parents[1] / "src"),
             "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr
    assert out.stdout.startswith("cold-ok 4")


# ---------------------------------------------------------------------------
# registry + follower protocol
# ---------------------------------------------------------------------------

def _marked_plan(gen, shapes, **cfg_over):
    tbl = {("gemm", shape_key(i)): (dict(CFG, g=gen, **cfg_over), "exact")
           for i in shapes}
    return DispatchPlan(generation=0, fingerprint="sim", store_version=-1,
                        table=tbl)


def test_registry_publish_current_pull(tmp_path):
    shapes = [gemm_input(128 * (i + 1), 64, 512) for i in range(3)]
    reg = PlanRegistry(tmp_path / "reg")
    assert reg.current() is None
    m1 = reg.publish(_marked_plan(1, shapes))
    m2 = reg.publish(_marked_plan(2, shapes))
    assert (m1.generation, m2.generation) == (1, 2)
    pointer = reg.current()
    assert pointer["generation"] == 2 and pointer["digest"] == m2.digest
    plan = reg.pull(pointer)
    assert plan.digest == m2.digest
    assert plan.lookup("gemm", shape_key(shapes[0]))[0]["g"] == 2
    # pointer/artifact divergence is caught at pull, not served
    bad = dict(pointer, digest="sha256:" + "0" * 64)
    with pytest.raises(PlanArtifactError, match="does not match"):
        reg.pull(bad)


def test_follower_installs_only_new_generations(tmp_path):
    shapes = [gemm_input(128, 64, 512)]
    reg = PlanRegistry(tmp_path / "reg")
    installed = []
    f = PlanFollower(reg, name="t",
                     install=lambda p, ptr: installed.append(ptr) or True,
                     current_plan=lambda: None)
    assert f.poll_once() is None        # nothing published yet
    reg.publish(_marked_plan(1, shapes))
    assert f.poll_once()["generation"] == 1
    assert f.poll_once() is None        # same generation: no reinstall
    assert (f.generation, f.installs, len(installed)) == (1, 1, 1)
    assert f.lag_s is not None and f.lag_s >= 0.0
    assert f.lag_generations() == 0
    st = f.stats()
    assert st["published_generation"] == 1 and st["running"] is False


def test_follower_refuses_generation_rollback(tmp_path):
    shapes = [gemm_input(128, 64, 512)]
    reg = PlanRegistry(tmp_path / "reg")
    reg.publish(_marked_plan(1, shapes))
    reg.publish(_marked_plan(2, shapes))
    holder = {}
    f = PlanFollower(reg, name="t",
                     install=lambda p, ptr: holder.update(p=p) or True,
                     current_plan=lambda: holder.get("p"))
    assert f.poll_once()["generation"] == 2
    # hand-roll a rollback: CURRENT repointed at generation 1
    old = json.loads((reg.generation_dir(1) / MANIFEST_NAME).read_text())
    old["path"] = "generations/00000001"
    (tmp_path / "reg" / "CURRENT.json").write_text(json.dumps(old))
    assert f.poll_once() is None
    assert f.refused_stale == 1 and f.generation == 2
    assert holder["p"].lookup("gemm", shape_key(shapes[0]))[0]["g"] == 2


def test_follower_refuses_torn_artifact_keeps_serving(tmp_path):
    shapes = [gemm_input(128, 64, 512)]
    reg = PlanRegistry(tmp_path / "reg")
    reg.publish(_marked_plan(1, shapes))
    holder = {}
    f = PlanFollower(reg, name="t",
                     install=lambda p, ptr: holder.update(p=p) or True,
                     current_plan=lambda: holder.get("p"))
    assert f.poll_once()["generation"] == 1
    reg.publish(_marked_plan(2, shapes))
    gen2 = reg.generation_dir(2) / ENTRIES_NAME
    gen2.write_bytes(gen2.read_bytes()[:10])        # torn pull
    assert f.poll_once() is None
    assert f.refused_digest == 1 and f.generation == 1
    assert holder["p"].lookup("gemm", shape_key(shapes[0]))[0]["g"] == 1


def test_follower_sentry_refuses_coverage_loss(tmp_path):
    shapes = [gemm_input(128 * (i + 1), 64, 512) for i in range(4)]
    reg = PlanRegistry(tmp_path / "reg")
    reg.publish(_marked_plan(1, shapes))
    holder = {}
    f = PlanFollower(reg, name="t", sentry=RegressionSentry(),
                     install=lambda p, ptr: holder.update(p=p) or True,
                     current_plan=lambda: holder.get("p"))
    assert f.poll_once()["generation"] == 1
    reg.publish(_marked_plan(2, shapes[:1]))        # drops 3 planned shapes
    with pytest.warns(RuntimeWarning, match="lose coverage"):
        assert f.poll_once() is None
    assert f.refused_sentry == 1 and f.generation == 1
    # a same-coverage generation then lands normally
    reg.publish(_marked_plan(3, shapes))
    assert f.poll_once()["generation"] == 3
    assert holder["p"].lookup("gemm", shape_key(shapes[0]))[0]["g"] == 3


def test_follower_default_target_is_global_serving(tmp_path):
    shapes = [gemm_input(128, 64, 512)]
    reg = PlanRegistry(tmp_path / "reg")
    reg.publish(_marked_plan(1, shapes))
    f = PlanFollower(reg, name="t", fingerprint="sim")
    assert f.poll_once()["generation"] == 1
    plan = serving_state().plan
    assert plan is not None and plan.source == "loaded"
    assert dispatch._tuned_cfg("gemm", gemm_input(128, 64, 512))["g"] == 1


def test_threaded_publish_race_no_torn_or_stale_reads(tmp_path):
    shapes = [gemm_input(128 * (i + 1), 64, 512) for i in range(8)]
    reg = PlanRegistry(tmp_path / "reg")
    holder = {}
    f = PlanFollower(reg, name="t", poll_s=0.001,
                     install=lambda p, ptr:
                     holder.update(p=(p, int(ptr["generation"]))) or True,
                     current_plan=lambda:
                     holder["p"][0] if "p" in holder else None)
    torn, stale, reads, last_gen = [], [], [0], [0]
    stop = threading.Event()

    def read_loop():
        while not stop.is_set():
            got = holder.get("p")
            if got is None:
                continue
            plan, gen = got
            if gen < last_gen[0]:
                stale.append(gen)
            last_gen[0] = max(last_gen[0], gen)
            markers = {plan.lookup("gemm", shape_key(s))[0]["g"]
                       for s in shapes}
            if len(markers) > 1:
                torn.append(markers)
            reads[0] += 1

    reader = threading.Thread(target=read_loop, daemon=True)
    f.start()
    reader.start()
    for gen in range(1, 9):
        reg.publish(_marked_plan(gen, shapes))
    deadline = threading.Event()
    for _ in range(500):                # wait for convergence, max 5s
        if f.generation == 8:
            break
        deadline.wait(0.01)
    stop.set()
    reader.join(timeout=5.0)
    f.stop()
    assert f.generation == 8
    assert reads[0] > 0 and torn == [] and stale == []


# ---------------------------------------------------------------------------
# publishers: retune controller + fleet coordinator
# ---------------------------------------------------------------------------

def test_controller_publishes_each_swap(tmp_path):
    from repro.tunedb.controller import RetuneConfig, RetuneController
    store = _seed_store(tmp_path / "s.jsonl")
    ctl = RetuneController(
        store, cfg=RetuneConfig(publish=str(tmp_path / "reg")))
    ctl._publish_plan(_compiled_plan(store))
    assert ctl.published_plans == 1 and ctl.publish_failed == 0
    assert ctl.stats()["published_plans"] == 1
    assert PlanRegistry(tmp_path / "reg").current()["generation"] == 1


def test_coordinator_publish_plan(tmp_path):
    from repro.tunedb.fleet import Coordinator
    store = _seed_store(tmp_path / "s.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    manifest = coord.publish_plan(tmp_path / "reg", fingerprint="test")
    assert manifest.generation == 1 and manifest.n_entries >= 4
    plan = PlanRegistry(tmp_path / "reg").pull(
        PlanRegistry(tmp_path / "reg").current())
    assert plan.fingerprint == "test"


# ---------------------------------------------------------------------------
# serving + CLI + observability surfaces
# ---------------------------------------------------------------------------

def test_engine_serves_from_plan_dir(tmp_path):
    import jax
    import jax.numpy as jnp
    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig
    store = _seed_store(tmp_path / "s.jsonl")
    dest = export_plan(_compiled_plan(store), tmp_path / "out", store=store)
    clear_store()
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    Engine(cfg, params, ServeConfig(
        max_len=64, slots=2, tunedb=str(tmp_path / "s.jsonl"),
        plan_dir=str(dest)))
    plan = serving_state().plan
    assert plan is not None and plan.source == "loaded"
    assert plan.digest == read_manifest(dest).digest


def test_cli_plan_export_inspect_publish_follow(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    store_path = tmp_path / "s.jsonl"
    _seed_store(store_path)
    out_dir = tmp_path / "artifacts"
    assert main(["plan", "export", "--store", str(store_path),
                 "--no-models", "--out", str(out_dir)]) == 0
    exported = capsys.readouterr().out
    assert "00000001" in exported and "entries" in exported
    dest = out_dir / "00000001"

    assert main(["plan", "inspect", str(dest)]) == 0
    inspected = json.loads(capsys.readouterr().out)
    assert inspected["verified"] is True
    assert inspected["digest"].startswith("sha256:")
    assert inspected["tiers"] == {"exact": 4}

    assert main(["plan", "publish", "--store", str(store_path),
                 "--no-models", "--registry", str(tmp_path / "reg")]) == 0
    capsys.readouterr()
    assert main(["plan", "follow", "--registry", str(tmp_path / "reg"),
                 "--store", str(store_path), "--interval", "0.01",
                 "--max-polls", "5"]) == 0
    follow_out = capsys.readouterr().out
    stats = json.loads(follow_out[follow_out.index("{"):])
    assert stats["installs"] == 1 and stats["generation"] == 1
    assert serving_state().plan.source == "loaded"


def test_cli_plan_export_stale_store_fails_cleanly(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    store_path = tmp_path / "s.jsonl"
    _seed_store(store_path)
    dest = export_plan(_compiled_plan(RecordStore.open(store_path)),
                       tmp_path / "out")
    (dest / ENTRIES_NAME).write_bytes(b"garbage\n")
    assert main(["plan", "inspect", str(dest)]) == 1
    assert "digest mismatch" in capsys.readouterr().err


def test_snapshot_and_metrics_carry_follower_and_plan_source(tmp_path):
    shapes = [gemm_input(128, 64, 512)]
    reg = PlanRegistry(tmp_path / "reg")
    reg.publish(_marked_plan(1, shapes))
    f = PlanFollower(reg, name="rep-0", fingerprint="sim")
    assert f.poll_once() is not None

    doc = status_snapshot()
    assert doc["serving"]["plan"]["source"] == "loaded"
    assert doc["follower"]["name"] == "rep-0"
    assert doc["follower"]["generation"] == 1

    text = get_registry().render_prometheus()
    assert 'tunedb_plan_source{source="loaded"} 1' in text
    assert 'tunedb_follower_generation{follower="rep-0"} 1' in text
    assert 'tunedb_follower_installs_total{follower="rep-0"} 1' in text
