"""MoE layer: routing, capacity, EP shard_map path, decode path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import auto_axis_types, make_mesh
from repro.models.moe import (init_moe, moe, moe_decode, moe_ep, _route,
                              _capacity)


@pytest.fixture(scope="module")
def layer():
    p = init_moe(jax.random.PRNGKey(0), 32, 64, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    return p, x


def test_moe_matches_dense_when_no_drops(layer):
    p, x = layer
    out, aux = moe(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    ref = moe_decode(p, x, n_experts=4, top_k=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)
    assert 0.5 < float(aux) < 4.0       # balanced-ish at init


def test_capacity_drops_reduce_output(layer):
    """Tiny capacity: some tokens dropped -> output differs from dense."""
    p, x = layer
    out_small, _ = moe(p, x, n_experts=4, top_k=2, capacity_factor=0.25)
    ref = moe_decode(p, x, n_experts=4, top_k=2)
    assert np.abs(np.asarray(out_small) - np.asarray(ref)).max() > 1e-3


def test_moe_ep_single_device_mesh(layer):
    """shard_map EP path on a 1-device mesh must equal the reference path."""
    p, x = layer
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=auto_axis_types(2))
    out_ep, aux_ep = moe_ep(p, x, n_experts=4, top_k=2,
                            capacity_factor=8.0, mesh=mesh)
    out_ref, aux_ref = moe(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(out_ep), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_ep), float(aux_ref), rtol=1e-5)


def test_route_renormalizes():
    logits = jnp.asarray([[0.0, 10.0, 0.0, 5.0]])
    w, idx = _route(logits, 2)
    assert np.allclose(np.asarray(w).sum(-1), 1.0)
    assert set(np.asarray(idx)[0]) == {1, 3}


def test_capacity_formula():
    assert _capacity(4096, 4, 16, 1.25) == 1280
    assert _capacity(1, 1, 128, 1.0) == 1


def test_moe_a2a_matches_on_multidevice():
    """All-to-all EP == reference MoE on a real 4-device mesh (subprocess:
    the main process must keep one device).  Aux loss is per-shard averaged
    (a deliberate, slightly different load-balance objective) — outputs must
    match exactly."""
    import subprocess
    import sys
    child = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.compat import auto_axis_types, make_mesh
from repro.models.moe import init_moe, moe, moe_ep, moe_ep_a2a
mesh = make_mesh((1, 4), ("data", "model"),
                 axis_types=auto_axis_types(2))
p = init_moe(jax.random.PRNGKey(0), 32, 64, 8, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
ref, _ = moe(p, x, n_experts=8, top_k=2, capacity_factor=8.0)
ep, _ = moe_ep(p, x, n_experts=8, top_k=2, capacity_factor=8.0, mesh=mesh)
np.testing.assert_allclose(np.asarray(ep), np.asarray(ref), rtol=1e-4, atol=1e-5)
a2a, _ = moe_ep_a2a(p, x, n_experts=8, top_k=2, capacity_factor=8.0, mesh=mesh)
np.testing.assert_allclose(np.asarray(a2a), np.asarray(ref), rtol=1e-4, atol=1e-5)
print("A2A-OK")
"""
    r = subprocess.run([sys.executable, "-c", child], capture_output=True,
                       text=True, timeout=380)
    assert "A2A-OK" in r.stdout, r.stdout + r.stderr
