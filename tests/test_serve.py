"""Serving: continuous batching consistency + flash-decoding math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params
from repro.models.layers import _chunked_attention
from repro.serve import Engine, ServeConfig
from repro.serve.flash_decode import flash_decode_attention


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


def test_flash_decode_equals_chunked(rng):
    """Split+combine partial softmax == sequential flash scan."""
    B, Sq, H, D, L, G = 2, 1, 4, 16, 64, 2
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, L, G, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, L, G, D)), jnp.float32)
    kv_len = jnp.asarray([40, 64])
    got = flash_decode_attention(q, k, v, kv_len, n_splits=4)
    want = _chunked_attention(q, k, v, causal=True,
                              q_start=kv_len - 1, kv_len=kv_len, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_continuous_batching_equals_single_slot(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, n) for n in (5, 9, 5, 7, 5)]
    multi = Engine(cfg, params, ServeConfig(max_len=64, slots=3))
    outs = multi.generate(prompts, max_new=8)
    for p, o in zip(prompts[:3], outs[:3]):
        ref = Engine(cfg, params, ServeConfig(max_len=64, slots=1)
                     ).generate([p], max_new=8)[0]
        assert ref == o


def test_slot_reuse_throughput(small_model):
    """More requests than slots: all served, ticks < sum of lengths
    (i.e. decoding genuinely batched)."""
    cfg, params = small_model
    rng = np.random.default_rng(1)
    eng = Engine(cfg, params, ServeConfig(max_len=64, slots=4))
    outs = eng.generate([rng.integers(0, 128, 6) for _ in range(8)],
                        max_new=10)
    assert all(len(o) == 10 for o in outs)
    assert eng.ticks < 8 * 9          # batched: fewer ticks than serial


def test_block_causal_attention_matches(rng):
    """The block-skipping causal path (perf hillclimb) is numerically
    identical to the masked chunked scan."""
    import jax.numpy as jnp
    from repro.models.layers import (_block_causal_attention,
                                     _chunked_attention)
    B, S, H, D, G = 2, 96, 4, 16, 2
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, G, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, G, D)), jnp.float32)
    got = _block_causal_attention(q, k, v, chunk=32)
    want = _chunked_attention(q, k, v, causal=True, q_start=0, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
