"""MLP performance regressor (paper §5) + log-feature transform."""

import jax
import numpy as np
import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.dataset import generate_dataset
from repro.core.features import Featurizer, target_transform
from repro.core.mlp import MLP, TABLE2_ARCHS
from repro.core.space import GEMM_SPACE


@pytest.fixture(scope="module")
def small_dataset():
    # 3k samples is too noisy to separate architectures/featurizations —
    # 10k is the smallest budget where the paper's effects are stable
    ds, _ = generate_dataset(GEMM_SPACE, 10000, seed=0,
                             backend=SimulatedTPUBackend(noise=0.02))
    return ds


def test_mlp_learns_performance_surface(small_dataset):
    tr, val = small_dataset.split(val_frac=0.1)
    f, X, y = tr.featurize()
    model = MLP.create(jax.random.PRNGKey(0), f.dim, hidden=(64, 128, 64))
    before = model.mse(*_xy(val, f))
    model.fit(X, y, epochs=40, verbose=False)
    after = model.mse(*_xy(val, f))
    assert after < before / 4, (before, after)
    assert after < 1.0           # log2-TFLOPS units


def test_log_transform_beats_raw(small_dataset):
    """Paper Table 2 'no log' column: without log features the fit is
    substantially worse at equal budget."""
    tr, val = small_dataset.split(val_frac=0.1)
    results = {}
    for log in (True, False):
        f = Featurizer(GEMM_SPACE, log=log)
        X_raw = f.raw_batch(list(zip(tr.inputs, tr.configs)))
        f.fit(X_raw)
        X = f.transform(X_raw)
        y = target_transform(tr.tflops)
        m = MLP.create(jax.random.PRNGKey(0), f.dim, hidden=(64, 128, 64))
        m.fit(X, y, epochs=40, verbose=False)
        results[log] = m.mse(*_xy(val, f))
    assert results[True] < results[False], results


def test_persistence_roundtrip(small_dataset):
    f, X, y = small_dataset.featurize()
    m = MLP.create(jax.random.PRNGKey(0), f.dim, hidden=(32, 32))
    m.fit(X[:500], y[:500], epochs=3, verbose=False)
    m2 = MLP.from_bytes(m.to_bytes())
    np.testing.assert_allclose(m.predict(X[:64]), m2.predict(X[:64]),
                               rtol=1e-6)
    f2 = Featurizer.from_json(GEMM_SPACE, f.to_json())
    np.testing.assert_allclose(f.mean, f2.mean)


def test_table2_archs_shapes():
    assert len(TABLE2_ARCHS) == 7            # the seven rows of Table 2


def _xy(ds, f):
    X = f.transform(f.raw_batch(list(zip(ds.inputs, ds.configs))))
    return X, target_transform(ds.tflops)
