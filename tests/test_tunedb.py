"""repro.tunedb: record store, shape telemetry, tuning sessions, CLI.

Pins the subsystem's contracts: append-only atomic persistence (a torn tail
line never poisons a store), exact + nearest-shape lookup, telemetry counting
under repeated kernel dispatch, the tuner<->store integration (best_config is
always a Dict[str, int] and survives process "restarts" through the store),
and the full telemetry -> session -> warm-started-serving round trip.
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import InputAwareTuner, clear_tuners
from repro.kernels import dispatch, ref
from repro.tunedb import (RecordStore, ShapeTelemetry, TuneRecord,
                          clear_store, clear_telemetry, get_telemetry,
                          input_key, install_store)
from repro.tunedb.session import TuningSession, backend_fingerprint
from repro.tunedb.__main__ import main as tunedb_main


@pytest.fixture(autouse=True)
def _clean_globals():
    clear_tuners()
    clear_store()
    clear_telemetry()
    yield
    clear_tuners()
    clear_store()
    clear_telemetry()


@pytest.fixture(scope="module")
def tiny_tuner():
    """A deliberately small trained tuner — enough to search, fast to build."""
    return InputAwareTuner.train(
        GEMM_SPACE, n_samples=600, hidden=(16, 16), epochs=4,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


def _rec(m, n, k, *, bm=64, tflops=100.0, created_at=0.0, bits=16):
    return TuneRecord(
        space="gemm", inputs=gemm_input(m, n, k, bits),
        config={"bm": bm, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
                "order": 0, "acc32": 1, "prefetch": 2},
        tflops=tflops, latency_us=12.5, backend="test", source="tuner",
        created_at=created_at)


# ---------------------------------------------------------------------------
# store
# ---------------------------------------------------------------------------

def test_record_json_roundtrip():
    rec = _rec(512, 16, 2048, created_at=123.0)
    back = TuneRecord.from_json(rec.to_json())
    assert back == rec
    assert back.key == input_key("gemm", rec.inputs)


def test_store_roundtrip_and_versioning(tmp_path):
    path = tmp_path / "db.jsonl"
    store = RecordStore.open(path)
    store.add(_rec(512, 16, 2048, bm=64, created_at=1.0))
    store.add(_rec(1024, 16, 2048, bm=128, created_at=2.0))
    # re-tune of the same shape: append-only, newest wins in the index
    store.add(_rec(512, 16, 2048, bm=256, created_at=3.0))

    fresh = RecordStore.open(path)
    assert len(fresh) == 2
    assert fresh.n_lines == 3                      # history preserved on disk
    hit = fresh.get("gemm", gemm_input(512, 16, 2048))
    assert hit is not None and hit.config["bm"] == 256


def test_store_atomicity_torn_tail(tmp_path):
    path = tmp_path / "db.jsonl"
    store = RecordStore.open(path)
    store.add(_rec(512, 16, 2048))
    store.add(_rec(1024, 16, 2048))
    with path.open("a") as fh:                     # simulate a crashed writer
        fh.write('{"space": "gemm", "inputs": {"M": 7')
    fresh = RecordStore.open(path)
    assert len(fresh) == 2
    assert fresh.n_skipped == 1
    # the store stays writable after recovery
    fresh.add(_rec(2048, 32, 2048))
    assert len(RecordStore.open(path)) == 3


def test_future_schema_records_are_skipped(tmp_path):
    path = tmp_path / "db.jsonl"
    store = RecordStore.open(path)
    store.add(_rec(512, 16, 2048))
    future = dict(json.loads(_rec(256, 256, 256).to_json()),
                  schema_version=99)
    with path.open("a") as fh:
        fh.write(json.dumps(future) + "\n")
    fresh = RecordStore.open(path)
    assert len(fresh) == 1                          # v99 record not misread
    assert fresh.n_skipped == 1


def test_nearest_shape_fallback():
    store = RecordStore()
    store.add(_rec(1024, 16, 2048, bm=128))
    store.add(_rec(64, 512, 512, bm=8))
    near = store.nearest("gemm", gemm_input(1152, 16, 2048))
    assert near is not None and near.config["bm"] == 128
    assert store.nearest_hits == 1
    # dtype must match exactly — no bf16 neighbor for an fp32 query
    assert store.nearest("gemm", gemm_input(1024, 16, 2048, 32)) is None
    # absurdly far shapes are not neighbors
    assert store.nearest("gemm", gemm_input(8, 8, 8)) is None
    # misses are the EXACT tier's to report (get); nearest() never
    # double-attributes them (see test_get_counts_misses_once)
    assert store.misses == 0


def test_get_counts_misses_once():
    """Dispatch's three-tier flow books exactly one miss per unserved exact
    lookup — previously get() never counted misses, so a model-tier serve
    after an exact miss made the store look better than it was; and the
    get->nearest chain double-counted the no-neighbor case."""
    store = RecordStore()
    store.add(_rec(1024, 16, 2048, bm=128))
    hot = gemm_input(1024, 16, 2048)
    assert store.get("gemm", hot) is not None
    assert (store.hits, store.misses) == (1, 0)
    # exact miss, regardless of what a later tier does with the shape
    assert store.get("gemm", gemm_input(8, 8, 8)) is None
    assert (store.hits, store.misses) == (1, 1)
    # the dispatch chain: get() misses (booked), nearest() finds no
    # neighbor — still ONE miss for the one resolution
    assert store.get("gemm", gemm_input(9, 9, 9)) is None
    assert store.nearest("gemm", gemm_input(9, 9, 9)) is None
    assert store.misses == 2
    # float-valued dims (JSON round trips) hit the same bucket
    assert store.get("gemm", {k: float(v) for k, v in hot.items()}) is not None
    assert store.stats()["lookups"] == {
        "hits": 2, "nearest": 0, "misses": 2}


def test_store_merge_and_export(tmp_path):
    a = RecordStore.open(tmp_path / "a.jsonl")
    a.add(_rec(512, 16, 2048, bm=64, created_at=1.0))
    a.add(_rec(512, 16, 2048, bm=128, created_at=5.0))  # newer duplicate
    b = RecordStore.open(tmp_path / "b.jsonl")
    b.add(_rec(512, 16, 2048, bm=256, created_at=3.0))  # older than a's
    b.add(_rec(256, 256, 256, created_at=4.0))

    merged = RecordStore.open(tmp_path / "m.jsonl")
    assert merged.merge(a) == 1
    assert merged.merge(b) == 1                    # only the novel shape lands
    assert merged.get("gemm", gemm_input(512, 16, 2048)).config["bm"] == 128

    out = tmp_path / "compact.jsonl"
    assert merged.export(out) == 2
    compact = RecordStore.open(out)
    assert len(compact) == 2 and compact.n_lines == 2


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_counts_and_hot_shapes(tmp_path):
    t = ShapeTelemetry()
    hot, cold = gemm_input(4096, 16, 2560), gemm_input(128, 128, 128)
    for _ in range(5):
        t.record("gemm", hot)
    t.record("gemm", cold)
    top = t.hot_shapes("gemm", top_k=1)
    assert top == [(hot, 5)]
    assert t.total("gemm") == 6

    t.save(tmp_path / "tel.json")
    back = ShapeTelemetry.load(tmp_path / "tel.json")
    assert back.count("gemm", hot) == 5
    back.merge(t)
    assert back.count("gemm", hot) == 10


def test_telemetry_under_repeated_dispatch(rng):
    tel = get_telemetry()
    a = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(32, 128)), jnp.float32)
    for _ in range(3):
        dispatch.matmul(a, b)
    dispatch.matmul(a[:8], b)
    shape3 = gemm_input(16, 128, 32, 32)
    assert tel.count("gemm", shape3) == 3
    assert tel.count("gemm", gemm_input(8, 128, 32, 32)) == 1
    assert tel.hot_shapes("gemm", 1)[0] == (shape3, 3)


def test_dispatch_integer_inputs_no_crash(rng):
    """conv2d/flash_attention used to jnp.finfo() integer dtypes and crash."""
    i = jnp.asarray(rng.integers(-2, 3, size=(1, 8, 8, 4)), jnp.int32)
    f = jnp.asarray(rng.integers(-2, 3, size=(3, 3, 4, 8)), jnp.int32)
    out = dispatch.conv2d(i, f)
    assert out.shape == (1, 8, 8, 8)
    assert get_telemetry().count(
        "conv", {"N": 1, "H": 8, "W": 8, "C": 4, "K": 8, "R": 3, "S": 3,
                 "dtype_bits": 32}) == 1


# ---------------------------------------------------------------------------
# tuner <-> store integration
# ---------------------------------------------------------------------------

def test_best_config_is_always_int_dict(tiny_tuner, tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    tuner = dataclasses.replace(tiny_tuner, store=store, _mem_cache={})
    inputs = gemm_input(896, 896, 32)

    c1 = tuner.best_config(inputs, remeasure=False)       # fresh search
    assert all(isinstance(v, int) for v in c1.values())
    assert GEMM_SPACE.contains(c1)

    tuner._mem_cache.clear()
    c2 = tuner.best_config(inputs, remeasure=False)       # store hit
    assert c2 == c1
    assert all(isinstance(v, int) for v in c2.values())

    rec = store.get("gemm", inputs)
    assert rec.tflops > 0 and rec.latency_us > 0
    assert rec.backend == backend_fingerprint(tuner.backend)


def test_store_survives_process_restart(tiny_tuner, tmp_path):
    """A second tuner (fresh mem cache) resolves from disk, not by searching."""
    path = tmp_path / "db.jsonl"
    inputs = gemm_input(2560, 16, 2560)
    t1 = dataclasses.replace(tiny_tuner, store=RecordStore.open(path),
                             _mem_cache={})
    want = t1.best_config(inputs, remeasure=False)

    t2 = dataclasses.replace(tiny_tuner, store=RecordStore.open(path),
                             _mem_cache={})
    t2.search = None                        # any search attempt would raise
    assert t2.best_config(inputs, remeasure=False) == want


def test_legacy_cache_dir_still_works(tiny_tuner, tmp_path):
    tuner = dataclasses.replace(tiny_tuner, cache_dir=str(tmp_path),
                                _mem_cache={}, _dir_store=None)
    inputs = gemm_input(896, 896, 32)
    c1 = tuner.best_config(inputs, remeasure=False)
    tuner._mem_cache.clear()
    assert tuner.best_config(inputs, remeasure=False) == c1
    assert (tmp_path / "tunedb.jsonl").exists()


def test_legacy_per_shape_cache_files_migrate(tiny_tuner, tmp_path):
    """Pre-store {space}-{key}.json files are honored and promoted."""
    inputs = gemm_input(777, 128, 512)
    key = input_key("gemm", inputs)
    legacy_cfg = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
                  "order": 0, "acc32": 1, "prefetch": 2}
    (tmp_path / f"gemm-{key}.json").write_text(json.dumps(legacy_cfg))

    tuner = dataclasses.replace(tiny_tuner, cache_dir=str(tmp_path),
                                _mem_cache={}, _dir_store=None)
    tuner.search = None                     # must not need a fresh search
    cfg = tuner.best_config(inputs, remeasure=False)
    assert cfg == legacy_cfg
    rec = RecordStore.open(tmp_path / "tunedb.jsonl").get("gemm", inputs)
    assert rec is not None and rec.source == "import"


# ---------------------------------------------------------------------------
# sessions
# ---------------------------------------------------------------------------

def test_session_tunes_hot_shapes_and_resumes(tiny_tuner, tmp_path):
    tel = ShapeTelemetry()
    for _ in range(9):
        tel.record("gemm", gemm_input(2560, 16, 2560))
    for _ in range(4):
        tel.record("gemm", gemm_input(512, 512, 512))
    tel.record("gemm", gemm_input(64, 128, 256))           # cold: not tuned

    store = RecordStore.open(tmp_path / "db.jsonl")
    progress = tmp_path / "progress.json"
    s1 = TuningSession(tiny_tuner, store, tel, top_k_shapes=2, workers=2,
                       remeasure=False, progress_path=progress)
    r1 = s1.run()
    assert r1.tuned == 2 and r1.failed == 0
    assert store.get("gemm", gemm_input(2560, 16, 2560)) is not None
    assert store.get("gemm", gemm_input(64, 128, 256)) is None
    assert set(json.loads(progress.read_text())["done"]) == \
        {rec.key for rec in r1.records}

    # resume: same session plan is fully satisfied -> zero new work
    s2 = TuningSession(tiny_tuner, store, tel, top_k_shapes=2, workers=2,
                       remeasure=False, progress_path=progress)
    r2 = s2.run()
    assert r2.tuned == 0 and r2.skipped == 2


def test_session_explicit_shapes_and_job_isolation(tiny_tuner, tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    s = TuningSession(tiny_tuner, store, None, remeasure=False, workers=2)
    # malformed shape (missing dtype_bits) -> that job fails, session survives
    bad = {"M": 512, "N": 512, "K": 512}
    r = s.run(shapes=[gemm_input(512, 512, 512), bad])
    assert r.tuned == 1 and r.failed == 1 and len(r.errors) == 1
    assert store.get("gemm", gemm_input(512, 512, 512)) is not None


# ---------------------------------------------------------------------------
# dispatch fallback + end-to-end round trip
# ---------------------------------------------------------------------------

def test_dispatch_falls_back_to_store_without_tuner(rng):
    store = RecordStore()
    store.add(_rec(64, 128, 128, bm=32, bits=32))
    install_store(store)

    a = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(128, 128)) / 12.0, jnp.float32)
    got = np.asarray(dispatch.matmul(a, b, prefer_kernel=True), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert store.hits == 1

    # nearest-shape: novel M rides its neighbor's config (ops clamps blocks)
    a2 = jnp.asarray(rng.normal(size=(48, 128)), jnp.float32)
    got2 = np.asarray(dispatch.matmul(a2, b, prefer_kernel=True), np.float32)
    np.testing.assert_allclose(got2, np.asarray(ref.matmul_ref(a2, b)),
                               rtol=1e-4, atol=1e-4)
    assert store.nearest_hits == 1


def test_e2e_telemetry_session_warmstart(tiny_tuner, tmp_path, rng):
    """The acceptance loop: dispatch populates telemetry, a session tunes the
    hot shapes into a store, and a 'fresh process' (cleared globals, store
    reopened from disk) serves the same shapes from store hits alone."""
    db = tmp_path / "tunedb.jsonl"
    a = jnp.asarray(rng.normal(size=(256, 512)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(512, 256)) / 23.0, jnp.bfloat16)

    # 1. traffic hits the dispatcher -> telemetry
    for _ in range(4):
        dispatch.matmul(a, b)
    assert get_telemetry().count("gemm", gemm_input(256, 256, 512)) == 4

    # 2. session tunes the hottest shapes into the store
    store = RecordStore.open(db)
    report = TuningSession(tiny_tuner, store, get_telemetry(),
                           top_k_shapes=1, remeasure=False).run()
    assert report.tuned == 1

    # 3. "fresh process": no tuner, no globals; warm-start from the store
    clear_tuners()
    clear_store()
    clear_telemetry()
    fresh = RecordStore.open(db)
    install_store(fresh)
    got = np.asarray(dispatch.matmul(a, b, prefer_kernel=True), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert fresh.hits == 1 and fresh.misses == 0


def test_engine_warmstart_installs_store(tmp_path):
    import jax

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig
    from repro.tunedb.store import get_store

    db = tmp_path / "serve.jsonl"
    RecordStore.open(db).add(_rec(512, 16, 2048))
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=32, slots=1,
                                             tunedb=str(db)))
    assert get_store() is engine.tunedb_store
    assert len(engine.tunedb_store) == 1


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_tune_stats_export_merge(tmp_path, capsys):
    tel = ShapeTelemetry()
    for _ in range(3):
        tel.record("gemm", gemm_input(512, 16, 512))
    tel.record("gemm", gemm_input(128, 128, 128))
    tel_path = tmp_path / "tel.json"
    tel.save(tel_path)
    db = tmp_path / "db.jsonl"

    rc = tunedb_main([
        "tune", "--space", "gemm", "--shapes-from-telemetry",
        "--telemetry", str(tel_path), "--store", str(db),
        "--top-k", "1", "--workers", "1", "--train-samples", "400",
        "--epochs", "2", "--no-remeasure",
        "--shape", "M=256,N=128,K=256"])
    assert rc == 0
    store = RecordStore.open(db)
    assert store.get("gemm", gemm_input(512, 16, 512)) is not None
    assert store.get("gemm", gemm_input(256, 128, 256)) is not None

    capsys.readouterr()                            # drain tune's output
    assert tunedb_main(["stats", "--store", str(db),
                        "--telemetry", str(tel_path)]) == 0
    stats = json.loads(capsys.readouterr().out)
    assert stats["store"]["shapes"] == 2
    assert stats["telemetry"]["spaces"]["gemm"]["calls"] == 4

    out = tmp_path / "export.jsonl"
    assert tunedb_main(["export", "--store", str(db),
                        "--out", str(out)]) == 0
    assert len(RecordStore.open(out)) == 2

    merged = tmp_path / "merged.jsonl"
    assert tunedb_main(["merge", str(db), str(out),
                        "--out", str(merged)]) == 0
    assert len(RecordStore.open(merged)) == 2


def test_cli_rejects_bad_shape(tmp_path):
    with pytest.raises(SystemExit):
        tunedb_main(["tune", "--space", "gemm", "--store",
                     str(tmp_path / "db.jsonl"), "--shape", "M=128"])
    # --shapes-from-telemetry without --telemetry: clean error, no traceback
    with pytest.raises(SystemExit):
        tunedb_main(["tune", "--space", "gemm", "--store",
                     str(tmp_path / "db.jsonl"), "--shapes-from-telemetry"])
