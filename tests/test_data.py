"""Data pipeline: determinism, sharding, resumability."""

import numpy as np

from repro.data import DataConfig, SyntheticTokenPipeline


def test_deterministic():
    p1 = SyntheticTokenPipeline(DataConfig(vocab=256, seq_len=64,
                                           global_batch=4, seed=7))
    p2 = SyntheticTokenPipeline(DataConfig(vocab=256, seq_len=64,
                                           global_batch=4, seed=7))
    np.testing.assert_array_equal(p1.batch(13)["tokens"],
                                  p2.batch(13)["tokens"])


def test_shards_partition_global_batch():
    full = SyntheticTokenPipeline(DataConfig(vocab=256, seq_len=32,
                                             global_batch=8, seed=3))
    parts = [SyntheticTokenPipeline(DataConfig(
        vocab=256, seq_len=32, global_batch=8, seed=3, n_shards=4, shard=i))
        for i in range(4)]
    got = np.concatenate([p.batch(5)["tokens"] for p in parts])
    np.testing.assert_array_equal(got, full.batch(5)["tokens"])


def test_resume_reproduces_stream():
    p = SyntheticTokenPipeline(DataConfig(vocab=128, seq_len=16,
                                          global_batch=2))
    direct = p.batch(42)["tokens"]
    it = p.iterate(start_step=42)
    np.testing.assert_array_equal(next(it)["tokens"], direct)


def test_learnable_structure():
    """Motif spans create repeated bigrams: bigram entropy must be clearly
    below the uniform bound."""
    p = SyntheticTokenPipeline(DataConfig(vocab=64, seq_len=2048,
                                          global_batch=2))
    toks = p.batch(0)["tokens"].reshape(-1)
    pairs = toks[:-1] * 64 + toks[1:]
    _, counts = np.unique(pairs, return_counts=True)
    probs = counts / counts.sum()
    ent = -(probs * np.log2(probs)).sum()
    assert ent < 11.0     # uniform would be ~12 bits
