"""Per-kernel correctness: Pallas (interpret=True) vs ref.py oracles,
swept over shapes / dtypes / tuning configurations."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import dispatch, ops, ref

GEMM_CONFIGS = [
    {"bm": 8, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
     "order": 0, "acc32": 1, "prefetch": 2},
    {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 2, "k_split": 2,
     "order": 1, "acc32": 1, "prefetch": 2},
    {"bm": 128, "bn": 256, "bk": 256, "k_unroll": 1, "k_split": 4,
     "order": 0, "acc32": 1, "prefetch": 3},
    {"bm": 32, "bn": 128, "bk": 128, "k_unroll": 4, "k_split": 1,
     "order": 0, "acc32": 0, "prefetch": 1},
]

GEMM_SHAPES = [(96, 200, 512), (256, 256, 256), (17, 130, 1000),
               (512, 16, 384)]


@pytest.mark.parametrize("cfg", GEMM_CONFIGS)
@pytest.mark.parametrize("shape", GEMM_SHAPES)
def test_gemm_allclose(cfg, shape, rng):
    M, N, K = shape
    for dtype in (jnp.float32, jnp.bfloat16):
        if dtype == jnp.float32 and not cfg["acc32"]:
            continue
        a = jnp.asarray(rng.normal(size=(M, K)), dtype)
        b = jnp.asarray(rng.normal(size=(K, N)) / K ** 0.5, dtype)
        got = np.asarray(ops.matmul(a, b, cfg), np.float32)
        want = np.asarray(ref.matmul_ref(a, b), np.float32)
        tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
        scale = max(np.abs(want).max(), 1e-6)
        assert np.abs(got - want).max() / scale < tol, cfg


@given(st.integers(1, 3), st.integers(3, 5), st.integers(3, 5),
       st.sampled_from([1, 16, 33]), st.sampled_from([32, 128]),
       st.sampled_from([(1, 1), (3, 3), (1, 5)]))
@settings(max_examples=8, deadline=None)
def test_conv_allclose_property(n, lh, lw, c, k, rs):
    h, w = 2 ** lh, 2 ** lw
    r, s = rs
    rng = np.random.default_rng(n * 1000 + c)
    i = jnp.asarray(rng.normal(size=(n, h, w, c)), jnp.float32)
    f = jnp.asarray(rng.normal(size=(r, s, c, k)) / (r * s * c) ** 0.5,
                    jnp.float32)
    cfg = {"b_npq": 64, "b_k": 128, "b_c": 32, "rs_unroll": 1,
           "c_split": 2 if c > 32 else 1, "order": 0, "acc32": 1,
           "prefetch": 2}
    got = np.asarray(ops.conv2d(i, f, cfg))
    want = np.asarray(ref.conv2d_ref(i, f))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("b_q,b_kv", [(64, 64), (128, 256)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_allclose(b_q, b_kv, causal, rng):
    B, Hq, Hkv, Lq, Lkv, D = 2, 4, 2, 192, 192, 32
    q = jnp.asarray(rng.normal(size=(B, Hq, Lq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Lkv, D)), jnp.float32)
    cfg = {"b_q": b_q, "b_kv": b_kv, "acc32": 1, "prefetch": 2}
    got = np.asarray(ops.flash_attention(q, k, v, cfg, causal=causal))
    want = np.asarray(ref.attention_ref(q, k, v, causal=causal))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("chunk,b_heads", [(32, 1), (64, 2)])
def test_ssd_allclose(chunk, b_heads, rng):
    B, L, H, P, S = 2, 160, 4, 16, 32
    x = jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, L, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(B, L, S)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(B, L, S)), jnp.float32)
    cfg = {"chunk": chunk, "b_heads": b_heads, "acc32": 1, "prefetch": 2}
    got = np.asarray(ops.ssd_scan(x, dt, a, bm, cm, cfg))
    want = np.asarray(ref.ssd_ref(x, dt, a, bm, cm))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_check_config_gate():
    """The InterpretBackend correctness gate catches what it should."""
    dispatch.check_config(
        "gemm",
        {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 2,
         "order": 0, "acc32": 1, "prefetch": 2},
        {"M": 128, "N": 128, "K": 512, "dtype_bits": 16})
