"""tunedb model serving: fingerprint-keyed lookup, trained-model dispatch,
artifact versioning, and graceful degradation of the serving path.

Pins the PR-2 contracts: the store index is keyed by (backend, space,
inputs) so one store serves several backends; `nearest` refuses dtype and
layout mismatches; a model trained from store records survives
persist -> fresh-process -> model-guided dispatch; unknown artifact schemas
and missing/torn stores degrade serving with a single warning instead of
taking it down; and the CLI round trip tune -> train -> predict works
against one store file.
"""

import json
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import enumerate_legal
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch, ref
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, install_store)
from repro.tunedb.model import (MODEL_SCHEMA_VERSION, ModelSet,
                                clear_models, collect_samples,
                                default_models_dir, harvest, install_models,
                                train_models)
from repro.tunedb.session import backend_fingerprint
from repro.tunedb.__main__ import main as tunedb_main

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


def _rec(m, n, k, *, backend="bk-A", bm=64, tflops=100.0, created_at=0.0,
         bits=16, **extra):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k, bits, **extra),
                      config=dict(CFG, bm=bm), tflops=tflops,
                      backend=backend, created_at=created_at)


# ---------------------------------------------------------------------------
# fingerprint-keyed store
# ---------------------------------------------------------------------------

def test_fingerprint_keyed_lookup_two_backends(tmp_path):
    """Same shape tuned on two backends -> two independent records."""
    path = tmp_path / "db.jsonl"
    store = RecordStore.open(path)
    store.add(_rec(512, 16, 2048, backend="bk-A", bm=64, created_at=1.0))
    store.add(_rec(512, 16, 2048, backend="bk-B", bm=256, created_at=2.0))

    fresh = RecordStore.open(path)
    assert len(fresh) == 2                        # one per (backend, shape)
    assert fresh.backends() == ["bk-A", "bk-B"]
    inputs = gemm_input(512, 16, 2048)
    assert fresh.get("gemm", inputs, backend="bk-A").config["bm"] == 64
    assert fresh.get("gemm", inputs, backend="bk-B").config["bm"] == 256
    assert fresh.get("gemm", inputs, backend="bk-C") is None
    # backend=None -> newest record regardless of backend
    assert fresh.get("gemm", inputs).config["bm"] == 256
    # nearest is fingerprint-filtered too
    near = fresh.nearest("gemm", gemm_input(640, 16, 2048), backend="bk-A")
    assert near is not None and near.backend == "bk-A"
    # export keeps both backends' records
    out = tmp_path / "export.jsonl"
    assert fresh.export(out) == 2


def test_nearest_rejects_dtype_and_layout_mismatch():
    store = RecordStore()
    store.add(_rec(1024, 16, 2048, bm=128))
    inputs = gemm_input(1152, 16, 2048)
    assert store.nearest("gemm", inputs) is not None
    # fp32 query must not borrow a bf16 neighbor
    assert store.nearest("gemm", gemm_input(1152, 16, 2048, 32)) is None
    # a transposed layout is not a neighbor of the plain layout
    assert store.nearest("gemm", gemm_input(1152, 16, 2048,
                                            trans_a=True)) is None
    assert store.nearest("gemm", gemm_input(1152, 16, 2048,
                                            trans_b=True)) is None


def test_sample_records_train_but_never_serve():
    store = RecordStore()
    store.add(_rec(512, 16, 2048, bm=64))
    store.add(TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                         config=dict(CFG, bm=8), tflops=1.0, backend="bk-A",
                         source="sample"))
    assert len(store) == 1
    assert store.n_samples == 1
    assert store.get("gemm", gemm_input(512, 16, 2048)).config["bm"] == 64
    assert len(store.training_records()) == 2     # the model sees both


# ---------------------------------------------------------------------------
# model training + serving
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_trained():
    """A small store + trained ModelSet shared by the model tests."""
    backend = SimulatedTPUBackend(noise=0.02)
    fp = backend_fingerprint(backend)
    store = RecordStore()
    for m, n, k in [(256, 128, 512), (512, 128, 512), (1024, 128, 1024),
                    (512, 256, 512)]:
        inputs = gemm_input(m, n, k)
        legal = enumerate_legal(GEMM_SPACE, inputs)
        scored = sorted(((c, backend.measure("gemm", c, inputs))
                         for c in legal[::7]), key=lambda t: -t[1])
        store.add(TuneRecord(space="gemm", inputs=inputs,
                             config=scored[0][0], tflops=scored[0][1],
                             backend=fp, source="session"))
    collect_samples(store, backend, per_shape=40, seed=0)
    models = train_models(store, epochs=8, hidden=(16, 16), seed=0)
    return store, models, fp, backend


def test_harvest_groups_by_space_and_backend(tiny_trained):
    store, _, fp, _ = tiny_trained
    store2 = RecordStore()
    for rec in store.training_records():
        store2.add(rec)
    store2.add(_rec(512, 16, 2048, backend="other-backend"))
    groups = harvest(store2)
    assert ("gemm", fp) in groups
    assert ("gemm", "other-backend") in groups
    assert len(groups[("gemm", fp)]) == len(store2.training_records()) - 1


def test_model_persist_fresh_process_dispatch_roundtrip(tiny_trained,
                                                        tmp_path, rng):
    """train -> persist -> 'fresh process' -> model-guided dispatch."""
    store, models, fp, _ = tiny_trained
    models.save(tmp_path / "models")

    # "fresh process": nothing installed, artifacts reloaded from disk
    clear_store()
    clear_models()
    loaded = ModelSet.load(tmp_path / "models")
    assert len(loaded) == 1 and not loaded.skipped
    serving_store = RecordStore()                 # empty: no exact, no nearest
    install_store(serving_store)
    install_models(loaded)

    a = jnp.asarray(rng.normal(size=(384, 256)), jnp.bfloat16)
    b = jnp.asarray(rng.normal(size=(256, 256)) / 16.0, jnp.bfloat16)
    got = np.asarray(dispatch.matmul(a, b, prefer_kernel=True), np.float32)
    want = np.asarray(ref.matmul_ref(a, b), np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert loaded.hits == 1                       # tier 2 served the shape
    assert serving_store.nearest_hits == 0        # tier 3 never consulted

    # second dispatch of the same shape: memo hit, still exactly one search
    np.asarray(dispatch.matmul(a, b, prefer_kernel=True))
    assert loaded.hits == 2


def test_model_remeasure_hook_picks_measured_best(tiny_trained):
    _, models, fp, backend = tiny_trained
    inputs = gemm_input(768, 128, 768)
    pure = models.predict("gemm", inputs, backend=fp)
    ms = ModelSet(measurer=backend.measure, remeasure_top_k=6)
    ms.models = models.models
    cfg, tflops = ms.predict("gemm", inputs, backend=fp)
    assert GEMM_SPACE.contains(cfg)
    # the re-measured winner's throughput is a real measurement
    assert tflops == pytest.approx(
        backend.measure("gemm", cfg, inputs))
    assert pure is not None


def test_unknown_model_schema_is_skipped_with_warning(tiny_trained, tmp_path):
    _, models, _, _ = tiny_trained
    d = tmp_path / "models"
    meta_path = next(iter(models.models.values())).save(d)
    payload = json.loads(meta_path.read_text())
    payload["model_schema_version"] = MODEL_SCHEMA_VERSION + 99
    meta_path.write_text(json.dumps(payload))

    with pytest.warns(RuntimeWarning, match="schema"):
        loaded = ModelSet.load(d)
    assert len(loaded) == 0
    assert len(loaded.skipped) == 1
    # a serving process keeps running on the lower tiers
    assert loaded.predict("gemm", gemm_input(512, 128, 512)) is None


def test_torn_artifact_is_skipped(tiny_trained, tmp_path):
    _, models, _, _ = tiny_trained
    d = tmp_path / "models"
    meta_path = next(iter(models.models.values())).save(d)
    meta_path.write_text('{"model_schema_version": 1, "space"')   # torn JSON
    with pytest.warns(RuntimeWarning):
        loaded = ModelSet.load(d)
    assert len(loaded) == 0 and loaded.skipped


def test_torn_npz_weights_are_skipped(tiny_trained, tmp_path):
    """A valid meta .json next to truncated weights must not crash load."""
    _, models, _, _ = tiny_trained
    d = tmp_path / "models"
    meta_path = next(iter(models.models.values())).save(d)
    npz = meta_path.with_suffix(".npz")
    npz.write_bytes(npz.read_bytes()[:20])        # crashed mid-write
    with pytest.warns(RuntimeWarning, match="damaged"):
        loaded = ModelSet.load(d)
    assert len(loaded) == 0 and loaded.skipped


# ---------------------------------------------------------------------------
# dispatch degradation
# ---------------------------------------------------------------------------

def test_dispatch_degrades_to_heuristics_and_warns_once(rng):
    install_store(RecordStore())                  # "healthy-looking" but empty
    a = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(64, 128)) / 8.0, jnp.float32)
    with pytest.warns(RuntimeWarning, match="heuristics"):
        got = np.asarray(dispatch.matmul(a, b, prefer_kernel=True),
                         np.float32)
    np.testing.assert_allclose(got, np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-4, atol=1e-4)
    # warn-once: the second miss is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        np.asarray(dispatch.matmul(a, b, prefer_kernel=True))


def test_engine_warns_on_missing_store_and_serves(tmp_path):
    import jax

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="does not exist"):
        engine = Engine(cfg, params, ServeConfig(
            max_len=32, slots=1, tunedb=str(tmp_path / "missing.jsonl")))
    assert len(engine.tunedb_store) == 0
    outs = engine.generate([np.arange(4)], max_new=4)
    assert len(outs[0]) == 4


def test_engine_warns_on_fully_torn_store(tmp_path):
    import jax

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    db = tmp_path / "torn.jsonl"
    db.write_text('{"space": "gemm", "inp\n{"gar\n')   # nothing parseable
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    with pytest.warns(RuntimeWarning, match="torn"):
        engine = Engine(cfg, params, ServeConfig(max_len=32, slots=1,
                                                 tunedb=str(db)))
    assert engine.tunedb_store.n_skipped == 2


def test_engine_warmstart_loads_models(tiny_trained, tmp_path):
    import jax

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig
    from repro.tunedb.model import get_models

    store, models, fp, _ = tiny_trained
    db = tmp_path / "serve.jsonl"
    disk = RecordStore.open(db)
    for rec in store.records():
        disk.add(rec)
    models.save(default_models_dir(db))           # auto-discovered sibling

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, ServeConfig(max_len=32, slots=1,
                                             tunedb=str(db)))
    assert get_models() is engine.tunedb_models
    assert len(engine.tunedb_models) == 1

    # a later Engine with a DIFFERENT store must not keep serving the
    # previous store's regressors (tunedb_models="" disables the tier)
    other = tmp_path / "other.jsonl"
    RecordStore.open(other).add(
        TuneRecord(space="gemm", inputs=gemm_input(512, 16, 2048),
                   config=dict(CFG), tflops=1.0))
    Engine(cfg, params, ServeConfig(max_len=32, slots=1, tunedb=str(other),
                                    tunedb_models=""))
    assert get_models() is None


# ---------------------------------------------------------------------------
# session sample collection
# ---------------------------------------------------------------------------

def test_session_skip_existing_is_fingerprint_scoped(tmp_path):
    """A shape tuned on another backend is NOT 'already tuned' here."""
    from repro.core.tuner import InputAwareTuner
    from repro.tunedb.session import TuningSession

    tuner = InputAwareTuner.train(
        GEMM_SPACE, n_samples=400, hidden=(8, 8), epochs=2,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)
    store = RecordStore.open(tmp_path / "db.jsonl")
    shape = gemm_input(512, 128, 512)
    store.add(TuneRecord(space="gemm", inputs=shape, config=dict(CFG),
                         tflops=50.0, backend="some-other-backend"))

    r = TuningSession(tuner, store, None, remeasure=False,
                      workers=1).run(shapes=[shape])
    assert r.tuned == 1 and r.skipped == 0        # other backend != tuned here
    fp = backend_fingerprint(tuner.backend)
    assert store.contains("gemm", shape, backend=fp)
    # and THIS fingerprint's record short-circuits the next session
    r2 = TuningSession(tuner, store, None, remeasure=False,
                       workers=1).run(shapes=[shape])
    assert r2.tuned == 0 and r2.skipped == 1


def test_best_config_is_fingerprint_scoped(tmp_path):
    """best_config must not serve another backend's record as its own."""
    from repro.core.tuner import InputAwareTuner

    tuner = InputAwareTuner.train(
        GEMM_SPACE, n_samples=400, hidden=(8, 8), epochs=2,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)
    store = RecordStore.open(tmp_path / "db.jsonl")
    shape = gemm_input(512, 128, 512)
    foreign = dict(CFG, bm=8, bn=1024)            # implausible tuned answer
    store.add(TuneRecord(space="gemm", inputs=shape, config=foreign,
                         tflops=50.0, backend="some-other-backend"))

    tuner.store = store
    cfg = tuner.best_config(shape, remeasure=False)
    fp = backend_fingerprint(tuner.backend)
    mine = store.get("gemm", shape, backend=fp)
    assert mine is not None                       # fresh search committed
    assert cfg == mine.config


def test_cli_predict_no_legal_config_fails_cleanly(tiny_trained, tmp_path,
                                                   capsys, monkeypatch):
    from repro.tunedb import model as model_mod

    _, models, _, _ = tiny_trained
    d = tmp_path / "models"
    models.save(d)

    def boom(self, inputs, *, top_k=1, candidates=None):
        raise ValueError(f"no legal configuration for inputs {inputs}")
    monkeypatch.setattr(model_mod.PerfModel, "predict_config", boom)
    rc = tunedb_main(["predict", "--models-dir", str(d), "--space", "gemm",
                      "--shape", "M=512,N=128,K=512"])
    assert rc == 1
    assert "predict failed" in capsys.readouterr().err


def test_session_commits_measured_topk_as_samples(tmp_path):
    from repro.core.tuner import InputAwareTuner
    from repro.tunedb.session import TuningSession

    tuner = InputAwareTuner.train(
        GEMM_SPACE, n_samples=400, hidden=(8, 8), epochs=2,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)
    store = RecordStore.open(tmp_path / "db.jsonl")
    report = TuningSession(tuner, store, None, remeasure=True,
                           workers=1).run(shapes=[gemm_input(512, 128, 512)])
    assert report.tuned == 1
    assert len(store) == 1                        # one serving record
    assert store.n_samples >= 5                   # losing top-k became samples
    # and they persist: a fresh open sees the same training log
    fresh = RecordStore.open(tmp_path / "db.jsonl")
    assert fresh.n_samples == store.n_samples
    assert len(fresh.training_records()) == 1 + store.n_samples


# ---------------------------------------------------------------------------
# CLI: tune -> train -> predict -> models from one store
# ---------------------------------------------------------------------------

def test_cli_train_predict_models_roundtrip(tmp_path, capsys):
    db = tmp_path / "db.jsonl"
    rc = tunedb_main([
        "tune", "--space", "gemm", "--store", str(db),
        "--train-samples", "400", "--epochs", "2", "--workers", "1",
        "--shape", "M=512,N=128,K=512", "--shape", "M=1024,N=128,K=512"])
    assert rc == 0

    rc = tunedb_main([
        "train", "--store", str(db), "--samples-per-shape", "30",
        "--min-samples", "20", "--epochs", "3", "--hidden", "16,16"])
    assert rc == 0
    assert default_models_dir(db).is_dir()

    capsys.readouterr()
    rc = tunedb_main(["predict", "--store", str(db), "--space", "gemm",
                      "--shape", "M=768,N=128,K=512", "--top-k", "3"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out.strip())
    assert GEMM_SPACE.contains(out["config"])
    assert out["predicted_tflops"] > 0
    assert len(out["top_k"]) == 3

    rc = tunedb_main(["models", "--store", str(db)])
    assert rc == 0
    stats = json.loads(capsys.readouterr().out)
    assert len(stats["models"]) == 1

    # stats reports the sample log
    rc = tunedb_main(["stats", "--store", str(db)])
    assert rc == 0
    st = json.loads(capsys.readouterr().out)
    assert st["store"]["sample_records"] >= 60


def test_cli_predict_without_model_fails_cleanly(tmp_path, capsys):
    rc = tunedb_main(["predict", "--store", str(tmp_path / "db.jsonl"),
                      "--space", "gemm", "--shape", "M=512,N=128,K=512"])
    assert rc == 1
    assert "no model" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# gate checker
# ---------------------------------------------------------------------------

def test_check_gates_validates_results(tmp_path, capsys):
    from benchmarks.check_gates import check

    d = tmp_path / "bench"
    d.mkdir()
    (d / "tunedb.json").write_text(json.dumps(
        {"overhead_frac": 0.01, "pass": True}))
    (d / "model.json").write_text(json.dumps(
        {"quality": {"pass": True, "geomean": 0.95, "threshold": 0.9,
                     "geomean_nearest": 0.9},
         "overhead": {"pass": True, "added_frac": 0.001, "cold_model_ms": 50},
         "pass": True}))
    (d / "other.json").write_text(json.dumps({"pass": True}))
    assert check(d, require=["tunedb", "model"]) == 0

    # a failing gate and a missing required file both fail the run
    (d / "model.json").write_text(json.dumps(
        {"quality": {"pass": False, "geomean": 0.5, "threshold": 0.9,
                     "geomean_nearest": 0.9},
         "overhead": {"pass": True, "added_frac": 0.001, "cold_model_ms": 50},
         "pass": False}))
    capsys.readouterr()
    assert check(d, require=["tunedb", "model"]) == 1
    assert check(d / "nope", require=["tunedb"]) == 1
    report = capsys.readouterr().out
    assert "FAIL" in report

    # a required-but-unparseable result file fails the run too
    (d / "model.json").write_text('{"quality": {"pa')
    assert check(d, require=["tunedb", "model"]) == 1
