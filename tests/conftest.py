"""Shared test configuration.

NOTE: no XLA_FLAGS / device-count forcing here — tests must see the real
single CPU device (the 512-device mesh exists only inside launch/dryrun.py,
and multi-device tests spawn subprocesses).
"""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
