"""Shared test configuration.

NOTE: no XLA_FLAGS / device-count forcing here — tests must see the real
single CPU device (the 512-device mesh exists only inside launch/dryrun.py,
and multi-device tests spawn subprocesses).
"""

import sys

import numpy as np
import pytest

try:
    import hypothesis  # noqa: F401
except ImportError:
    # container lacks hypothesis; run property tests on the deterministic stub
    import importlib.util
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_stub.py")
    _stub = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_stub)
    sys.modules["hypothesis"] = _stub


@pytest.fixture
def rng():
    return np.random.default_rng(0)
