"""Sharding rules: logical->physical resolution and divisibility dropping."""

import numpy as np
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import logical_to_spec, _axes_for


class FakeMesh:
    """Duck-typed mesh: logical_to_spec only touches axis_names/devices."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


POD = FakeMesh((16, 16), ("data", "model"))
MULTI = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def test_batch_spreads_over_pod_and_data():
    spec = logical_to_spec(("batch", "none"), (256, 4096), MULTI)
    assert spec == P(("pod", "data"), None)


def test_divisibility_drop():
    # 9 heads cannot shard over model=16 -> axis dropped
    spec = logical_to_spec(("none", "none", "model", "none"),
                           (2, 64, 9, 64), POD)
    assert spec == P(None, None, None, None)
    # 48 heads can
    spec = logical_to_spec(("none", "none", "model", "none"),
                           (2, 64, 48, 64), POD)
    assert spec == P(None, None, "model", None)


def test_no_axis_reuse():
    # expert dim takes 'model'; a later 'model' axis must not reuse it
    spec = logical_to_spec(("expert", "fsdp", "model"),
                           (16, 6144, 10752), POD)
    assert spec == P("model", "data", None)


def test_partial_batch_sharding_on_multipod():
    # batch=32 over pod(2) x data(16) = 32 exactly
    spec = logical_to_spec(("batch", "none"), (32, 128), MULTI)
    assert spec == P(("pod", "data"), None)
    # batch=2: only 'pod' fits
    spec = logical_to_spec(("batch", "none"), (2, 128), MULTI)
    assert spec == P(("pod",), None) or spec == P("pod", None)


def test_param_rules_match_paths():
    assert _axes_for("params/layers/pos0/attn/wq", 3, True) \
        == ("none", "fsdp", "model")
    assert _axes_for("params/layers/pos0/moe/w_gate", 4, True) \
        == ("none", "expert", "fsdp", "model")
    assert _axes_for("params/embed", 2, False) == ("model", "fsdp")
    assert _axes_for("params/final_norm", 1, False) == ("none",)
