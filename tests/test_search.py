"""Runtime kernel inference (paper §6): exhaustive search over the model."""

import pytest

from repro.core.backend import SimulatedTPUBackend
from repro.core.search import enumerate_legal, oracle_search
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import InputAwareTuner


@pytest.fixture(scope="module")
def tuner():
    return InputAwareTuner.train(
        GEMM_SPACE, n_samples=4000, hidden=(64, 64), epochs=25,
        backend=SimulatedTPUBackend(noise=0.02), seed=0)


def test_search_returns_legal_best(tuner):
    inputs = gemm_input(2560, 16, 2560)
    res = tuner.search(inputs)
    assert GEMM_SPACE.is_legal(res.best, inputs)
    assert res.n_candidates > 100
    assert res.measured_tflops is not None


def test_topk_remeasure_improves_or_equal(tuner):
    """Re-measuring the top-k on the backend can only improve the pick."""
    inputs = gemm_input(512, 512, 8192)
    no_meas = tuner.search(inputs, remeasure=False)
    meas = tuner.search(inputs, remeasure=True)
    be = tuner.backend
    y_no = be.measure("gemm", no_meas.best, inputs)
    assert meas.measured_tflops >= y_no * 0.95


def test_regret_vs_oracle(tuner):
    """ISAAC regret: the tuned config should reach a large fraction of the
    true optimum (paper Fig. 6: ISAAC ~ matches exhaustive best)."""
    be = SimulatedTPUBackend(noise=0.0)
    for m, n, k in [(2560, 32, 2560), (512, 512, 512), (64, 64, 60000)]:
        inputs = gemm_input(m, n, k)
        cands = enumerate_legal(GEMM_SPACE, inputs)
        best_cfg, best = oracle_search(
            GEMM_SPACE, inputs, lambda c: be.measure("gemm", c, inputs),
            candidates=cands)
        res = tuner.search(inputs)
        got = be.measure("gemm", res.best, inputs)
        assert got >= 0.7 * best, (m, n, k, got, best)


def test_cache_hit(tuner, tmp_path):
    tuner.cache_dir = str(tmp_path)
    inputs = gemm_input(896, 896, 32)
    c1 = tuner.best_config(inputs)
    tuner._mem_cache.clear()
    c2 = tuner.best_config(inputs)        # filesystem hit
    assert c1 == c2
