"""Docs-drift gate: the documentation layer must track the actual
surfaces.

Every ``python -m repro.tunedb`` subcommand (including the nested
``fleet``/``plan`` verbs) and every ``ServeConfig`` field must be
mentioned somewhere in README.md or docs/ — adding a CLI verb or a
serving knob without documenting it fails CI here, not in review.
"""

import argparse
import dataclasses
import re
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _docs_text() -> str:
    parts = [(REPO / "README.md").read_text(encoding="utf-8")]
    for p in sorted((REPO / "docs").glob("*.md")):
        parts.append(p.read_text(encoding="utf-8"))
    return "\n".join(parts)


def _subcommands(parser: argparse.ArgumentParser):
    for a in parser._actions:
        if isinstance(a, argparse._SubParsersAction):
            return a.choices
    return {}


def test_docs_exist():
    for name in ("README.md", "docs/PLANS.md", "docs/ARCHITECTURE.md",
                 "docs/OBSERVABILITY.md", "docs/ROBUSTNESS.md"):
        assert (REPO / name).is_file(), f"{name} is missing"


def test_readme_has_the_tier1_verify_command():
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    assert "PYTHONPATH=src" in readme
    assert "python -m pytest" in readme


def test_every_tunedb_subcommand_is_documented():
    from repro.tunedb.__main__ import build_parser
    text = _docs_text()
    missing = []
    for name, sub in _subcommands(build_parser()).items():
        if name not in text:
            missing.append(name)
        for nested in _subcommands(sub):
            # nested verbs are documented as "<parent> <verb>"
            if not re.search(rf"{name}\s+{nested}", text):
                missing.append(f"{name} {nested}")
    assert not missing, f"undocumented tunedb subcommand(s): {missing}"


def test_every_serveconfig_field_is_documented():
    from repro.serve import ServeConfig
    text = _docs_text()
    missing = [f.name for f in dataclasses.fields(ServeConfig)
               if f.name not in text]
    assert not missing, f"undocumented ServeConfig field(s): {missing}"


def test_readme_architecture_map_names_every_package():
    readme = (REPO / "README.md").read_text(encoding="utf-8")
    pkgs = sorted(p.name for p in (REPO / "src" / "repro").iterdir()
                  if p.is_dir() and p.name != "__pycache__")
    missing = [p for p in pkgs if f"`{p}/`" not in readme
               and f"repro/{p}" not in readme]
    assert not missing, f"README architecture map misses: {missing}"


def test_tracing_docs_cover_the_surface():
    """The tracing section must name the CLI verbs, the endpoint route,
    the config knobs, and the span taxonomy's load-bearing names."""
    obs = (REPO / "docs" / "OBSERVABILITY.md").read_text(encoding="utf-8")
    for needle in ("trace export", "trace summary", "/trace",
                   "trace_sample", "measure", "Perfetto",
                   "dispatch.resolve", "engine.tick", "request.route",
                   "retune.epoch", "fleet.job", "plan.install",
                   "measure.wallclock",
                   "tunedb_measurements_total"):
        assert needle in obs, f"OBSERVABILITY.md lost mention of {needle!r}"


def test_docs_crosslink_each_other():
    obs = (REPO / "docs" / "OBSERVABILITY.md").read_text(encoding="utf-8")
    arch = (REPO / "docs" / "ARCHITECTURE.md").read_text(encoding="utf-8")
    rob = (REPO / "docs" / "ROBUSTNESS.md").read_text(encoding="utf-8")
    assert "PLANS.md" in obs and "ARCHITECTURE.md" in obs
    assert "PLANS.md" in arch and "OBSERVABILITY.md" in arch
    assert "ROBUSTNESS.md" in arch
    assert "ARCHITECTURE.md" in rob and "OBSERVABILITY.md" in rob


def test_robustness_docs_cover_the_surface():
    """The robustness page must name the chaos harness surface, the
    failure-mode machinery, and the degradation knobs."""
    rob = (REPO / "docs" / "ROBUSTNESS.md").read_text(encoding="utf-8")
    for needle in ("FaultPlan", "FaultRule", "KillPoint", "torn_write",
                   "retry_io", "fsck", "--repair", "quarantine",
                   "request_deadline_s", "shed_threshold", "/healthz",
                   "retune_window_s", "bench_chaos",
                   "tunedb_io_retries_total",
                   "tunedb_store_quarantined_lines_total",
                   "tunedb_requests_shed_total"):
        assert needle in rob, f"ROBUSTNESS.md lost mention of {needle!r}"
