"""End-to-end request tracing + wall-clock profiling (PR 9).

Pins the tracing contracts: deterministic stride sampling with an
always-keep escape for explicit trace ids; lock-free ring completion and
bounded retention; Chrome trace-event export that Perfetto can load;
torn/partial span files skipped (never raised) by the fleet exporter; a
coordinator-published job's trace id showing up on the worker's session
spans after the merge; and the acceptance trace — one live Engine run
whose export contains linked spans for a router decision, a decode tick,
a dispatch tier resolution (with tier attribute), and a §6 measurement.
"""

import json
import threading
import urllib.error
import urllib.request
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.models import ModelConfig, init_params
from repro.serve import Engine, ServeConfig
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry)
from repro.tunedb.fleet import Coordinator, FleetJob, Worker
from repro.tunedb.measure import MeasureQueue, ServingMeasurer
from repro.tunedb.model import clear_models
from repro.tunedb.obs import StatusServer, status_snapshot
from repro.tunedb.obs.metrics import get_registry, reset_metrics
from repro.tunedb.obs.trace import (FLEET_TRACE_DIR, Span, Tracer,
                                    collect_fleet_spans, enable_tracing,
                                    get_tracer, load_span_file,
                                    new_trace_id, reset_tracing,
                                    summarize_spans)
from repro.tunedb.__main__ import main as tunedb_main

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        reset_tracing()
        reset_metrics()
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


def _rec(m, n, k, *, backend="test", tflops=100.0):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k),
                      config=dict(CFG), tflops=tflops, backend=backend,
                      source="tuner", created_at=0.0)


# ---------------------------------------------------------------------------
# tracer core: sampling, nesting, rings, retention
# ---------------------------------------------------------------------------

def test_stride_sampling_is_deterministic():
    tr = Tracer(sample=0.5)                 # stride 2: every 2nd root kept
    kept = []
    for i in range(10):
        with tr.root("r", i=i) as sp:
            kept.append(sp is not None)
    assert kept == [False, True] * 5        # reproducible, not random
    assert tr.sampled == 5 and tr.dropped == 5
    assert all(sp.attrs["i"] % 2 == 1 for sp in tr.spans())


def test_explicit_trace_id_bypasses_sampling():
    tr = Tracer(sample=0.0)                 # stride 0: drop every minted root
    with tr.root("dropped") as sp:
        assert sp is None
    tid = new_trace_id()
    with tr.root("adopted", trace_id=tid) as sp:
        assert sp is not None and sp.trace_id == tid
    spans = tr.spans()
    assert [s.name for s in spans] == ["adopted"]


def test_child_spans_nest_and_link():
    tr = Tracer(sample=1.0)
    with tr.root("parent") as root:
        with tr.span("child", tier="exact") as child:
            assert child.trace_id == root.trace_id
            assert child.parent_id == root.span_id
    names = {s.name for s in tr.spans()}
    assert names == {"parent", "child"}


def test_span_without_open_root_is_shared_noop():
    tr = Tracer(sample=1.0)
    a = tr.span("orphan")
    b = tr.span("orphan2")
    assert a is b                           # one shared _NULL_SPAN instance
    with a as sp:
        assert sp is None
    assert tr.spans() == []                 # nothing recorded


def test_unsampled_root_suppresses_children():
    tr = Tracer(sample=0.0)
    with tr.root("r") as sp:
        assert sp is None
        with tr.span("child") as c:
            assert c is None                # no context pushed -> no-op
    assert tr.spans() == []


def test_detached_begin_end_crosses_threads():
    tr = Tracer(sample=1.0)
    sp = tr.begin("retune.epoch", trace_id=new_trace_id(), spaces="gemm")
    t = threading.Thread(target=lambda: tr.end(sp, outcome="swapped"))
    t.start()
    t.join()
    [got] = tr.spans()
    assert got.name == "retune.epoch"
    assert got.attrs["outcome"] == "swapped" and got.dur >= 0.0


def test_rings_drain_from_worker_threads():
    tr = Tracer(sample=1.0)

    def work():
        for _ in range(50):
            with tr.root("w"):
                pass

    threads = [threading.Thread(target=work) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert tr.buffered() == 200             # finished spans sit in rings
    assert len(tr.spans()) == 200           # spans() drains them all
    assert tr.buffered() == 0


def test_retention_cap_evicts_and_counts_overflow():
    tr = Tracer(sample=1.0, max_spans=10)
    for _ in range(25):
        with tr.root("r"):
            pass
    assert len(tr.spans()) == 10
    assert tr.stats()["overflow"] > 0


def test_stats_shape():
    tr = Tracer(sample=0.25)
    st = tr.stats()
    for key in ("enabled", "sample", "sampled", "dropped", "spans",
                "buffered", "overflow", "max_spans", "tiers"):
        assert key in st
    assert st["enabled"] is True and st["sample"] == 0.25


def test_tier_latency_attribution():
    tr = Tracer(sample=1.0)
    for tier in ("plan", "plan", "model"):
        with tr.root("t"):
            with tr.span("dispatch.resolve", tier=tier, space="gemm"):
                pass
    tiers = tr.tier_latency()
    assert tiers["plan"]["count"] == 2 and tiers["model"]["count"] == 1
    assert tiers["plan"]["mean_us"] >= 0.0


# ---------------------------------------------------------------------------
# export + torn-tolerant loading
# ---------------------------------------------------------------------------

def test_chrome_export_round_trips(tmp_path):
    tr = Tracer(sample=1.0)
    with tr.root("engine.tick", tick=3):
        with tr.span("dispatch.resolve", tier="exact"):
            pass
    out = tmp_path / "trace.json"
    assert tr.export(out) == 2
    doc = json.loads(out.read_text())
    assert doc["otherData"]["schema"] == 1
    evs = doc["traceEvents"]
    assert len(evs) == 2
    for ev in evs:
        assert ev["ph"] == "X" and ev["cat"] == "tunedb"
        assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
        assert ev["args"]["trace_id"]
    # parent links survive the round trip through the Chrome doc
    back = load_span_file(out)
    by_name = {s.name: s for s in back}
    assert (by_name["dispatch.resolve"].parent_id
            == by_name["engine.tick"].span_id)


def test_export_jsonl_clears_retention(tmp_path):
    tr = Tracer(sample=1.0)
    with tr.root("a"):
        pass
    p = tmp_path / "w.jsonl"
    assert tr.export_jsonl(p) == 1
    assert tr.spans() == []                 # dump moved them out
    with tr.root("b"):
        pass
    assert tr.export_jsonl(p) == 1          # appends, no duplicates
    assert [s.name for s in load_span_file(p)] == ["a", "b"]


def test_torn_jsonl_line_is_skipped_not_raised(tmp_path):
    good = Span("fleet.job", "t1", "s1")
    good.t0, good.dur = 1.0, 0.5
    p = tmp_path / "w.jsonl"
    p.write_text(json.dumps(good.to_json()) + "\n"
                 + '{"name": "fleet.job", "trace_id": "t2", "spa')
    spans = load_span_file(p)               # crashed worker mid-write
    assert [s.trace_id for s in spans] == ["t1"]


def test_torn_chrome_document_is_skipped_whole(tmp_path):
    p = tmp_path / "t.json"
    p.write_text('{"traceEvents": [{"name": "x", "ph": "X", "ts"')
    assert load_span_file(p) == []          # mid-rename file: drop it
    p.write_text("\x00\x01 not json at all")
    assert load_span_file(p) == []
    assert load_span_file(tmp_path / "missing.json") == []


def test_collect_fleet_spans_merges_and_survives_junk(tmp_path):
    traces = tmp_path / FLEET_TRACE_DIR
    traces.mkdir()
    sp = Span("fleet.job", "tid9", "s1")
    sp.t0, sp.dur = 1.0, 0.1
    (traces / "w1.jsonl").write_text(json.dumps(sp.to_json()) + "\n")
    (traces / "w2.jsonl").write_text('{"torn')
    (traces / "w3.json").write_text("garbage")
    (traces / "notes.txt").write_text("ignored: wrong suffix")
    spans = collect_fleet_spans(tmp_path)
    assert [s.trace_id for s in spans] == ["tid9"]
    assert collect_fleet_spans(tmp_path / "nofleet") == []


def test_summarize_spans_counts_names_traces_tiers():
    tr = Tracer(sample=1.0)
    with tr.root("engine.tick"):
        with tr.span("dispatch.resolve", tier="nearest"):
            pass
    with tr.root("engine.tick"):
        pass
    s = summarize_spans(tr.spans())
    assert s["spans"] == 3 and s["traces"] == 2
    assert s["names"]["engine.tick"]["count"] == 2
    assert s["tiers"]["nearest"]["count"] == 1


# ---------------------------------------------------------------------------
# process-global enable/reset
# ---------------------------------------------------------------------------

def test_enable_tracing_installs_and_retunes_sample():
    assert get_tracer() is None
    tr = enable_tracing(1.0)
    assert get_tracer() is tr
    assert enable_tracing(0.25) is tr       # same tracer, new stride
    assert tr.sample == 0.25
    reset_tracing()
    assert get_tracer() is None


# ---------------------------------------------------------------------------
# fleet propagation: job trace id -> worker session spans -> merge
# ---------------------------------------------------------------------------

class _StubTuner:
    """Instant deterministic tuner; fleet tracing is about propagation,
    not search quality."""

    space = None
    backend = None

    def __init__(self):
        from repro.core.backend import SimulatedTPUBackend
        from repro.core.space import GEMM_SPACE
        self.space = GEMM_SPACE
        self.backend = SimulatedTPUBackend(noise=0.0)

    def search(self, inputs, remeasure=True):
        from repro.core.search import SearchResult
        cfg = dict(CFG)
        tf = float(self.backend.measure("gemm", cfg, inputs))
        return SearchResult(best=cfg, predicted_tflops=tf,
                            measured_tflops=tf, top_k=[(cfg, tf)],
                            n_candidates=1, measured=[(cfg, tf)])


def test_job_trace_id_reaches_worker_spans_after_merge(tmp_path):
    """The controller stamps its epoch's trace id into the job JSON; the
    worker's ``fleet.job`` span must adopt it (bypassing sampling), and
    the done marker must carry it back for the coordinator's merge."""
    enable_tracing(0.0)                     # sample=0: ONLY adoption keeps
    store = RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    tid = new_trace_id()
    job = FleetJob(space="gemm", inputs=gemm_input(256, 64, 512),
                   source="retune", trace_id=tid)
    assert coord.publish([job]) == 1
    # the bus round-trips the id through JSON (unknown-field-tolerant)
    w = Worker(tmp_path / "fleet", worker_id="w1",
               tuners={"gemm": _StubTuner()})
    assert w.run_one() is True
    merged = coord.poll()
    assert merged["merged_now"] >= 1
    tr = get_tracer()
    jobs = [s for s in tr.spans() if s.name == "fleet.job"]
    assert len(jobs) == 1
    assert jobs[0].trace_id == tid          # linked across the bus
    assert jobs[0].attrs["outcome"] == "tuned"
    assert jobs[0].attrs["job"] == job.job_id
    # the done marker carries the id too (debuggability of the bus state)
    done = list((tmp_path / "fleet" / "done").glob("*.json"))
    assert any(json.loads(p.read_text()).get("trace_id") == tid
               for p in done)


def test_fleet_job_json_roundtrip_keeps_trace_id():
    job = FleetJob(space="gemm", inputs=gemm_input(128, 64, 256),
                   trace_id="abc123")
    back = FleetJob.from_json(job.to_json())
    assert back.trace_id == "abc123"
    # and a pre-PR-9 job document (no trace_id field) still loads
    d = json.loads(job.to_json())
    d.pop("trace_id")
    assert FleetJob.from_json(json.dumps(d)).trace_id == ""


def test_worker_trace_export_dumps_to_bus(tmp_path):
    enable_tracing(0.0)
    store = RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    tid = new_trace_id()
    coord.publish([FleetJob(space="gemm", inputs=gemm_input(256, 64, 512),
                            source="retune", trace_id=tid)])
    w = Worker(tmp_path / "fleet", worker_id="wX",
               tuners={"gemm": _StubTuner()}, poll_s=0.01,
               trace_export=True)          # the `fleet worker` CLI mode
    w.run(idle_timeout_s=0.3)
    spans = collect_fleet_spans(tmp_path / "fleet")
    assert any(s.name == "fleet.job" and s.trace_id == tid for s in spans)


# ---------------------------------------------------------------------------
# serving measurer + deferred measurement queue
# ---------------------------------------------------------------------------

def test_wallclock_off_hardware_warns_once_and_counts():
    m = ServingMeasurer("wallclock")
    inputs = gemm_input(256, 64, 512)
    if jax.default_backend() == "tpu":
        pytest.skip("fallback path needs a non-TPU host")
    with pytest.warns(RuntimeWarning, match="without TPU hardware"):
        tf = m("gemm", dict(CFG), inputs)
    assert tf > 0.0
    # warn ONCE: the second call must stay quiet
    with warnings.catch_warnings(record=True) as record:
        warnings.simplefilter("always")
        m("gemm", dict(CFG), inputs)
    assert not [w for w in record if issubclass(w.category, RuntimeWarning)]
    assert m.stats()["counts"]["sim"] == 2
    text = get_registry().render_prometheus()
    assert 'tunedb_measurements_total{backend="sim"} 2' in text


def test_measurer_records_always_kept_span():
    enable_tracing(0.0)                     # even at sample=0...
    m = ServingMeasurer("sim")
    m("gemm", dict(CFG), gemm_input(256, 64, 512))
    spans = get_tracer().spans()
    assert [s.name for s in spans] == ["measure.sim"]
    assert spans[0].attrs["backend"] == "sim"
    assert spans[0].attrs["tflops"] > 0.0


def test_measure_queue_commits_winner_to_models_and_dedupes():
    q = MeasureQueue(maxlen=4)
    inputs = gemm_input(512, 64, 1024)
    cands = [dict(CFG, bm=32), dict(CFG, bm=64)]
    assert q.push("gemm", "bk", inputs, cands)
    assert not q.push("gemm", "bk", inputs, cands)      # deduped
    applied = []

    class _Models:
        def apply_measurement(self, space, backend, inp, cfg, tflops):
            applied.append((space, backend, dict(inp), dict(cfg), tflops))

    m = ServingMeasurer("sim")
    assert q.process(m, models=_Models(), max_items=2) == 1
    assert len(q) == 0 and q.processed == 1
    [(space, backend, inp, cfg, tflops)] = applied
    assert space == "gemm" and backend == "bk" and tflops > 0.0
    assert cfg in cands                     # measured winner, not a mutant
    # the shape may be re-queued after processing (memo now covers it,
    # but the queue itself must not block a future push)
    assert q.push("gemm", "bk", inputs, cands)


# ---------------------------------------------------------------------------
# tick_times bounding (satellite bugfix)
# ---------------------------------------------------------------------------

def test_tick_times_bounded_and_still_sliceable(small_model):
    cfg, params = small_model
    eng = Engine(cfg, params, ServeConfig(max_len=64, slots=2,
                                          record_tick_times=True,
                                          tick_times_cap=8))
    rng = np.random.default_rng(0)
    eng.generate([rng.integers(0, 128, 6) for _ in range(3)], max_new=16)
    assert eng.ticks > 8                    # enough ticks to overflow cap
    assert len(eng.tick_times) == 8         # bounded: newest 8 kept
    assert isinstance(eng.tick_times, list)
    tail = eng.tick_times[5:]               # bench/test read surface: slices
    assert len(tail) == 3
    assert all(w > 0.0 for _t0, w, _ in eng.tick_times)


@pytest.fixture(scope="module")
def small_model():
    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    return cfg, init_params(cfg, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# the acceptance trace: one live Engine run, exported + parsed
# ---------------------------------------------------------------------------

def test_live_engine_trace_has_linked_spans(tmp_path, small_model):
    """ISSUE 9 acceptance: the exported Chrome trace from a live run
    contains linked spans for a router decision, a decode tick, a
    dispatch tier resolution carrying its tier, and a measurement."""
    cfg, params = small_model
    db = tmp_path / "db.jsonl"
    RecordStore.open(db).add(_rec(512, 16, 2048))
    eng = Engine(cfg, params, ServeConfig(
        max_len=48, slots=2, tunedb=str(db), router="round_robin",
        trace_sample=1.0, measure="sim"))
    assert eng.tracer is not None and eng.tracer is get_tracer()
    rng = np.random.default_rng(0)
    eng.generate([rng.integers(0, 64, 8) for _ in range(3)], max_new=8)

    out = tmp_path / "trace.json"
    n = eng.tracer.export(out)
    assert n > 0
    doc = json.loads(out.read_text())
    assert doc["otherData"]["schema"] == 1
    evs = doc["traceEvents"]
    by_name = {}
    for ev in evs:
        assert ev["ph"] == "X" and "ts" in ev and "dur" in ev
        by_name.setdefault(ev["name"], []).append(ev)

    # a router decision, linked under its admission root
    route = by_name["request.route"][0]
    assert route["args"]["policy"] == "round_robin"
    admits = {e["args"]["span_id"]: e for e in by_name["engine.admit"]}
    assert route["args"]["parent_id"] in admits
    assert (route["args"]["trace_id"]
            == admits[route["args"]["parent_id"]]["args"]["trace_id"])

    # decode ticks with their census tick number
    ticks = by_name["engine.tick"]
    assert len(ticks) >= 2 and all("tick" in e["args"] for e in ticks)

    # dispatch resolutions carry the winning tier + shape, child-linked
    # (the startup probe resolves installed shapes under its own root —
    # on TPU the decode compile emits these under the tick spans too)
    resolves = by_name["dispatch.resolve"]
    all_ids = {e["args"]["span_id"] for e in evs}
    assert all(e["args"]["tier"] in ("plan", "exact", "model", "nearest",
                                     "degraded", "tuner", "none")
               for e in resolves)
    assert all("shape" in e["args"] for e in resolves)
    assert any(e["args"]["parent_id"] in all_ids for e in resolves)

    # the §6 measurement rides the same clock (calibration guarantees one)
    measures = by_name["measure.sim"]
    assert measures[0]["args"]["backend"] == "sim"
    assert measures[0]["args"]["tflops"] > 0.0

    # prefill nests under admission in the same trace
    prefill = by_name["engine.prefill"][0]
    assert prefill["args"]["parent_id"] in admits


def test_status_snapshot_and_trace_endpoint(tmp_path):
    # disabled: schema keeps the key, route 404s
    snap = status_snapshot()
    assert snap["schema"] == 1 and snap["trace"] is None
    srv = StatusServer(port=0).start()
    try:
        with pytest.raises(urllib.error.HTTPError) as exc:
            urllib.request.urlopen(srv.url + "/trace", timeout=10)
        assert exc.value.code == 404
    finally:
        srv.stop()

    # enabled: the snapshot section and the route serve the same tracer
    tr = enable_tracing(1.0)
    with tr.root("engine.tick", tick=1):
        with tr.span("dispatch.resolve", tier="exact", space="gemm"):
            pass
    snap = status_snapshot()
    assert snap["trace"]["enabled"] is True
    assert snap["trace"]["tiers"]["exact"]["count"] == 1
    srv = StatusServer(port=0).start()
    try:
        with urllib.request.urlopen(srv.url + "/trace", timeout=10) as r:
            doc = json.loads(r.read().decode())
    finally:
        srv.stop()
    assert {e["name"] for e in doc["traceEvents"]} \
        == {"engine.tick", "dispatch.resolve"}


# ---------------------------------------------------------------------------
# CLI: tunedb trace export / summary
# ---------------------------------------------------------------------------

def _dump_spans(path):
    tr = Tracer(sample=1.0)
    with tr.root("engine.tick", tick=1):
        with tr.span("dispatch.resolve", tier="plan", space="gemm"):
            pass
    tr.export_jsonl(path)


def test_cli_trace_export_and_summary(tmp_path, capsys):
    src = tmp_path / "spans.jsonl"
    _dump_spans(src)
    out = tmp_path / "merged.json"
    assert tunedb_main(["trace", "export", "--input", str(src),
                        "--out", str(out)]) == 0
    doc = json.loads(out.read_text())
    assert len(doc["traceEvents"]) == 2
    assert "perfetto" in capsys.readouterr().out.lower()

    assert tunedb_main(["trace", "summary", "--input", str(src),
                        "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["spans"] == 2
    assert summary["tiers"]["plan"]["count"] == 1


def test_cli_trace_summary_merges_fleet_dumps(tmp_path, capsys):
    fleet = tmp_path / "fleet"
    (fleet / FLEET_TRACE_DIR).mkdir(parents=True)
    _dump_spans(fleet / FLEET_TRACE_DIR / "w1.jsonl")
    (fleet / FLEET_TRACE_DIR / "w2.jsonl").write_text('{"torn')
    assert tunedb_main(["trace", "summary", "--fleet", str(fleet),
                        "--json"]) == 0
    summary = json.loads(capsys.readouterr().out)
    assert summary["spans"] == 2            # torn dump skipped, not fatal
