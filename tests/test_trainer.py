"""End-to-end trainer: convergence, microbatching, compression, resume."""

import jax.numpy as jnp
import pytest

from repro.data import DataConfig
from repro.models import ModelConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainConfig


@pytest.fixture
def small_cfg():
    return ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                       d_ff=128, vocab=128, dtype=jnp.float32,
                       attn_chunk=32, logit_chunk=32)


def test_loss_decreases_and_resumes(small_cfg, tmp_path):
    mk = lambda steps: Trainer(
        small_cfg,
        AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40),
        TrainConfig(steps=steps, microbatches=2, compress_grads=True,
                    checkpoint_every=5, checkpoint_dir=str(tmp_path),
                    log_every=100),
        DataConfig(vocab=128, seq_len=64, global_batch=4))
    t1 = mk(12)
    res = t1.run(verbose=False)
    h = res["history"]
    assert h[-1]["loss"] < h[0]["loss"]
    t2 = mk(14)
    state, start = t2.init_or_resume()
    assert start == 12          # resumed from the exit snapshot
    res2 = t2.run(verbose=False)
    assert len(res2["history"]) == 2          # only steps 12, 13 run


def test_microbatch_equivalence(small_cfg):
    """microbatches=2 computes the same averaged gradient direction: losses
    after a few steps track the microbatches=1 run closely."""
    import numpy as np
    runs = {}
    for nm in (1, 2):
        tr = Trainer(small_cfg,
                     AdamWConfig(lr=5e-4, warmup_steps=0, total_steps=20),
                     TrainConfig(steps=6, microbatches=nm, log_every=100),
                     DataConfig(vocab=128, seq_len=64, global_batch=4))
        runs[nm] = [h["loss"] for h in tr.run(verbose=False)["history"]]
    np.testing.assert_allclose(runs[1], runs[2], rtol=2e-2)
