"""Optimizer + gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_grads, decompress_grads,
                         init_error_feedback)
from repro.optim.adamw import _stochastic_round


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, opt, _ = adamw_update(params, grads, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0)
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    _, _, m = adamw_update(params, {"w": jnp.full(4, 100.0)}, opt, cfg)
    assert float(m["grad_norm"]) > 100          # reported raw norm


def test_bf16_states_roundtrip():
    cfg = AdamWConfig(state_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8, 8))}
    opt = adamw_init(params, cfg)
    assert opt.m["w"].dtype == jnp.bfloat16
    params, opt, _ = adamw_update(params, {"w": jnp.ones((8, 8))}, opt, cfg)
    assert opt.v["w"].dtype == jnp.bfloat16


@given(st.floats(-100, 100).filter(lambda x: abs(x) > 1e-3))
@settings(max_examples=20, deadline=None)
def test_stochastic_rounding_unbiased(val):
    key = jax.random.PRNGKey(42)
    x = jnp.full((2048,), val, jnp.float32)
    r = _stochastic_round(key, x, jnp.bfloat16).astype(jnp.float32)
    # mean of stochastic rounding approximates the fp32 value much better
    # than deterministic rounding error bound (bf16 has ~3 decimal digits)
    assert abs(float(r.mean()) - val) < abs(val) * 4e-3 + 1e-6


def test_compression_error_feedback_property(rng):
    """EF invariant: quantized + error == original (exactly, per step)."""
    g = {"a": jnp.asarray(rng.normal(size=(64,)), jnp.float32)}
    ef = init_error_feedback(g)
    q, s, ef2 = compress_grads(g, ef)
    assert q["a"].dtype == jnp.int8
    recon = decompress_grads(q, s)
    np.testing.assert_allclose(np.asarray(recon["a"] + ef2["a"]),
                               np.asarray(g["a"]), rtol=1e-5, atol=1e-6)


def test_compression_converges_sgd(rng):
    """int8+EF SGD still reaches the optimum of a quadratic."""
    w = jnp.asarray(rng.normal(size=(16,)) * 5, jnp.float32)
    ef = init_error_feedback({"w": w})
    for _ in range(300):
        g = {"w": 2 * w}
        q, s, ef = compress_grads(g, ef)
        w = w - 0.05 * decompress_grads(q, s)["w"]
    assert float(jnp.abs(w).max()) < 0.05
