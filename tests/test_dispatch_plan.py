"""Frozen dispatch plans (PR 5): install-time resolution, indexed nearest
lookup, lock-free telemetry rings, store-aware admission.

Pins the tentpole contracts: ``install_serving`` compiles the generation's
(store, ModelSet, telemetry hot set) into one flat DispatchPlan so the
steady-state hot path is a single lock-free probe; the plan stands aside the
moment the store gains a record (a frozen entry never shadows fresher
tuning); concurrent hot-swaps never serve a torn or stale-generation entry;
the log2-bucketed ``nearest()`` index answers exactly what the linear scan
answered; the per-thread telemetry rings lose no counts under threaded
writers racing a drainer; and store-aware admission pads a shape to a tuned
neighbor only when the recorded-TFLOPS arithmetic says the overhead wins.
"""

import threading

import numpy as np
import pytest

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.serve.engine import StoreAwareAdmission
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, compile_plan, get_telemetry,
                          install_serving, install_store, serving_state,
                          shape_key)
from repro.tunedb.telemetry import RING_SIZE, record_shape

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        from repro.tunedb.model import clear_models
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


def _rec(m, n, k, *, backend="test", tflops=100.0, **cfg_over):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k),
                      config=dict(CFG, **cfg_over), tflops=tflops,
                      backend=backend)


# ---------------------------------------------------------------------------
# plan compilation + the tier-0 hot path
# ---------------------------------------------------------------------------

def test_install_compiles_exact_records_into_the_plan():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_store(store)
    state = serving_state()
    assert state.plan is not None
    assert state.plan.generation == state.generation
    entry = state.plan.lookup("gemm", shape_key(gemm_input(512, 16, 2048)))
    assert entry is not None and entry[1] == "exact"
    assert entry[0] == CFG


def test_plan_hit_serves_without_store_traffic_and_keeps_stats():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_store(store)
    plan = serving_state().plan
    cfg = dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048))
    assert cfg == CFG
    # the hit was served by the plan, credited to the exact tier
    assert plan.hits == 1 and store.hits == 1 and store.misses == 0
    # nothing touched the nearest machinery
    assert not store._nearest_memo


def test_hot_telemetry_shapes_are_preresolved_at_install():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    # traffic on a shape only the neighbor tier can serve
    get_telemetry().record("gemm", gemm_input(600, 16, 2048), n=8)
    install_store(store)
    plan = serving_state().plan
    entry = plan.lookup("gemm", shape_key(gemm_input(600, 16, 2048)))
    assert entry is not None and entry[1] == "nearest"
    # serving it is a plan hit that still counts as a nearest-tier serve
    dispatch._tuned_cfg("gemm", gemm_input(600, 16, 2048))
    assert store.nearest_hits == 1 and plan.hits == 1


def test_slow_path_resolution_is_promoted_into_the_plan():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_store(store)
    plan = serving_state().plan
    novel = gemm_input(700, 16, 2048)
    assert plan.lookup("gemm", shape_key(novel)) is None
    assert dispatch._tuned_cfg("gemm", novel) == CFG     # nearest, slow path
    entry = plan.lookup("gemm", shape_key(novel))
    assert entry is not None and entry[1] == "nearest"
    before = store.nearest_hits
    assert dispatch._tuned_cfg("gemm", novel) == CFG     # now a plan hit
    assert plan.hits == 1 and store.nearest_hits == before + 1


def test_store_append_stands_the_plan_aside_until_reinstall():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_store(store)
    plan = serving_state().plan
    assert store.version == plan.store_version
    # a retune session commits a fresh record mid-generation
    store.add(_rec(512, 16, 2048, bm=32, tflops=140.0))
    assert store.version != plan.store_version
    # dispatch must serve the FRESH record, not the frozen entry
    cfg = dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048))
    assert cfg["bm"] == 32
    assert plan.hits == 0                # the plan stood aside entirely
    # the next install recompiles and the plan serves again
    install_store(store)
    plan2 = serving_state().plan
    assert plan2.store_version == store.version
    assert dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048))["bm"] == 32
    assert plan2.hits == 1


def test_models_only_serving_still_builds_a_plan():
    class _Models:
        def predict(self, space, inputs, backend=None):
            return dict(CFG, bm=16), 50.0
    get_telemetry().record("gemm", gemm_input(256, 16, 256), n=4)
    install_serving(store=None, models=_Models())
    plan = serving_state().plan
    entry = plan.lookup("gemm", shape_key(gemm_input(256, 16, 256)))
    assert entry is not None and entry[1] == "model"
    assert dispatch._tuned_cfg("gemm", gemm_input(256, 16, 256))["bm"] == 16


def test_build_plan_false_keeps_the_slow_path():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_serving(store=store, build_plan=False)
    assert serving_state().plan is None
    assert dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048)) == CFG
    assert store.hits == 1


def test_compile_plan_respects_fingerprint_pin():
    store = RecordStore()
    store.add(_rec(512, 16, 2048, backend="a"))
    store.add(_rec(1024, 16, 2048, backend="b", bm=32))
    plan = compile_plan(store, None, "b")
    assert plan.lookup("gemm", shape_key(gemm_input(512, 16, 2048))) is None
    entry = plan.lookup("gemm", shape_key(gemm_input(1024, 16, 2048)))
    assert entry is not None and entry[0]["bm"] == 32


# ---------------------------------------------------------------------------
# plan/swap concurrency: no torn or stale-generation entries
# ---------------------------------------------------------------------------

def test_concurrent_swaps_never_serve_torn_or_stale_plan():
    """Readers racing install_serving flips must only ever see a config
    belonging to SOME complete generation, and a plan stamped with the
    generation of the state it was read from."""
    shape = gemm_input(512, 16, 2048)
    store_a, store_b = RecordStore(), RecordStore()
    store_a.add(_rec(512, 16, 2048, bm=32))
    store_b.add(_rec(512, 16, 2048, bm=64))
    install_serving(store=store_a)

    stop = threading.Event()
    errors = []

    def reader():
        while not stop.is_set():
            state = serving_state()
            if state.plan is not None \
                    and state.plan.generation != state.generation:
                errors.append(("stale plan", state.plan.generation,
                               state.generation))
            cfg = dispatch._tuned_cfg("gemm", shape)
            if cfg is None or cfg["bm"] not in (32, 64):
                errors.append(("torn config", cfg))

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    try:
        for i in range(60):
            install_serving(store=store_b if i % 2 else store_a)
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert not errors, errors[:5]


# ---------------------------------------------------------------------------
# telemetry rings: lock-free recording, lossless draining
# ---------------------------------------------------------------------------

def test_ring_drain_loses_no_counts_under_threaded_writers():
    clear_telemetry()
    tel = get_telemetry()
    n_threads, per_thread = 6, 4000
    start = threading.Barrier(n_threads + 1)
    done = threading.Event()

    def writer(tid):
        shape = gemm_input(128 * (tid + 1), 16, 128)
        start.wait()
        for _ in range(per_thread):
            record_shape("gemm", shape)

    def drainer():
        start.wait()
        while not done.is_set():
            tel.drain_pending()

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    dr = threading.Thread(target=drainer)
    for t in threads + [dr]:
        t.start()
    for t in threads:
        t.join()
    done.set()
    dr.join()
    assert tel.total("gemm") == n_threads * per_thread
    for i in range(n_threads):
        assert tel.count("gemm", gemm_input(128 * (i + 1), 16, 128)) \
            == per_thread


def test_full_ring_falls_back_to_locked_path_without_loss():
    clear_telemetry()
    tel = get_telemetry()
    n = RING_SIZE * 2 + 17               # overflow the ring with no drain
    for _ in range(n):
        record_shape("gemm", gemm_input(64, 16, 64))
    assert tel.total("gemm") == n        # total() drains, then counts


def test_captures_still_attribute_with_buffered_recording():
    clear_telemetry()
    tel = get_telemetry()
    record_shape("gemm", gemm_input(64, 16, 64))     # pre-capture backlog
    with tel.capture() as cap:
        record_shape("gemm", gemm_input(128, 16, 128))
    assert cap.shapes == [("gemm", gemm_input(128, 16, 128))]
    assert tel.count("gemm", gemm_input(64, 16, 64)) == 1


# ---------------------------------------------------------------------------
# the log2-bucketed nearest index
# ---------------------------------------------------------------------------

def _random_store(rng, n=400):
    store = RecordStore()
    backends = ["a", "b"]
    for i in range(n):
        m, nn, k = (int(2 ** rng.uniform(4, 13)) for _ in range(3))
        store.add(TuneRecord(
            space="gemm", inputs=gemm_input(m, nn, k),
            config=dict(CFG, bm=16 + 16 * (i % 4)),
            tflops=float(rng.uniform(10, 150)),
            backend=backends[i % 2]))
    return store


def test_indexed_nearest_matches_linear_scan(rng):
    from repro.tunedb.store import _shape_distance
    store = _random_store(rng)
    for _ in range(120):
        m, n, k = (int(2 ** rng.uniform(4, 13)) for _ in range(3))
        q = gemm_input(m, n, k)
        for backend in (None, "a", "b"):
            got = store._nearest_indexed("gemm", q, backend, 2.0)
            want = store._nearest_linear("gemm", q, backend, 2.0)
            assert (got is None) == (want is None)
            if got is not None:
                # equal-distance ties may pick different records; the
                # DISTANCE (the serving contract) must match exactly
                assert _shape_distance(q, got.inputs) == pytest.approx(
                    _shape_distance(q, want.inputs))
                if backend is not None:
                    assert got.backend == backend


def test_indexed_nearest_rejects_exact_param_mismatch():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    # fp32 is not a neighbor of bf16, however close the dims
    assert store.nearest("gemm", gemm_input(512, 16, 2048, 32)) is None
    assert store.nearest("gemm", gemm_input(520, 16, 2048)) is not None


def test_nearest_index_invalidated_by_append():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    assert store.nearest("gemm", gemm_input(4000, 16, 2048),
                         count=False) is None      # too far: > max_distance
    store.add(_rec(4096, 16, 2048, bm=32))
    got = store.nearest("gemm", gemm_input(4000, 16, 2048), count=False)
    assert got is not None and got.config["bm"] == 32


# ---------------------------------------------------------------------------
# store-aware admission
# ---------------------------------------------------------------------------

def test_bucket_pads_only_when_recorded_tflops_say_it_wins():
    store = RecordStore()
    # a mediocre tuned shape at 512 and a fast one at 1024
    store.add(_rec(512, 64, 1024, bm=512, bn=64, tflops=60.0))
    store.add(_rec(1024, 64, 1024, bm=512, bn=64, tflops=100.0))
    install_store(store)
    adm = StoreAwareAdmission()
    # tuned shape: nothing to decide
    shape, how = adm.bucket("gemm", gemm_input(1024, 64, 1024))
    assert how == "hit" and shape["M"] == 1024
    # M=530: the nearest record (512) pays ~0.52 block quantization, the
    # 1024 record padded delivers 100 * 530/1024 ~ 51.8 > 60 * 0.52 ~ 31
    shape, how = adm.bucket("gemm", gemm_input(530, 64, 1024))
    assert how == "padded" and shape["M"] == 1024
    # M=500 aligns almost perfectly with the 512 record: stay exact
    shape, how = adm.bucket("gemm", gemm_input(500, 64, 1024))
    assert how == "exact" and shape["M"] == 500
    assert adm.padded == 1 and adm.exact == 1


def test_bucket_respects_max_pad_budget():
    store = RecordStore()
    store.add(_rec(4096, 64, 1024, bm=512, bn=64, tflops=100.0))
    install_store(store)
    adm = StoreAwareAdmission(max_pad=0.25)
    # padding 530 -> 4096 is ~7.7x extra work: over any sane budget
    shape, how = adm.bucket("gemm", gemm_input(530, 64, 1024))
    assert how == "exact" and shape["M"] == 530


def test_admission_pick_prefers_plan_hit_lengths_and_groups():
    store = RecordStore()
    store.add(_rec(8, 16, 64))
    install_store(store)
    state = serving_state()

    class _Req:
        def __init__(self, n):
            self.prompt = np.zeros(n, np.int32)

    # length 8 prefill runs a tuned gemm; length 5 runs an untuned one
    prefill_shapes = {8: [("gemm", gemm_input(8, 16, 64))],
                      5: [("gemm", gemm_input(5, 16, 64))]}
    adm = StoreAwareAdmission()
    pending = [_Req(5), _Req(8), _Req(8)]
    assert adm.pick(pending, prefill_shapes) == 1        # tuned length first
    assert adm.pick(pending, prefill_shapes, last_len=8) == 1
    # unknown lengths (must compile) rank above known-untuned ones
    pending2 = [_Req(5), _Req(7)]
    assert adm.pick(pending2, prefill_shapes) == 1
    # grouping: equal-length reuse breaks what would otherwise tie
    pending3 = [_Req(5), _Req(5)]
    assert adm.pick(pending3, prefill_shapes, last_len=5) == 0
    del state


def test_engine_store_admission_serves_identical_outputs(tmp_path):
    """Admission reorders WHICH request fills a slot first, never what any
    request computes: greedy outputs must match FIFO exactly."""
    import jax
    import jax.numpy as jnp

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=1, d_model=32, n_heads=2, n_kv=1,
                      d_ff=64, vocab=64, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 64, n) for n in (6, 9, 6, 9, 6)]

    outs = {}
    for mode in ("fifo", "store"):
        clear_store()
        clear_telemetry()
        eng = Engine(cfg, params, ServeConfig(max_len=64, slots=2,
                                              admission=mode))
        outs[mode] = eng.generate([p.copy() for p in prompts], max_new=4)
        if mode == "store":
            assert eng.admission is not None
    assert outs["fifo"] == outs["store"]
