"""Fleet-global telemetry + shape-affinity routing (PR 8).

Pins the fleet-scope refactor's contracts: a cumulative telemetry dump on
the bus aggregates (latest epoch per worker, torn reads fall back) into
exactly the counts the in-process collectors hold — even under concurrent
ring writers; per-replica provenance round-trips; the retune controller
triggers off aggregated multi-replica mass that no single replica's window
would have tripped; the coordinator partitions the global hot set into
balanced per-replica affinity classes and publishes SMALL specialized
plans; the router lands covered requests on their plan's replica inside a
load bound with a no-starvation escape; `/status` drains pending rings
before serializing; and `resolve_decode_splits` routes flash-decoding's
split count through tuned dispatch with the caller's value as fallback.
"""

import json
import threading

import pytest

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.serve.router import (RandomRouter, Replica, RoundRobinRouter,
                                ShapeAffinityRouter, make_router,
                                plan_coverage)
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, get_telemetry, install_store,
                          serving_state)
from repro.tunedb.controller import RetuneConfig, RetuneController
from repro.tunedb.fleet import Coordinator
from repro.tunedb.model import clear_models
from repro.tunedb.obs.snapshot import status_snapshot
from repro.tunedb.plans import PlanRegistry
from repro.tunedb.store import DispatchPlan, shape_key
from repro.tunedb.telemetry import (FleetTelemetryView, ShapeTelemetry,
                                    TelemetryExporter)

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}

ATTN_CFG = {"b_q": 128, "b_kv": 512, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
    reset()
    yield
    reset()


def _shape(i: int):
    return gemm_input(256 * (i + 1), 64, 512)


def _rec(inputs, *, space="gemm", cfg=None, backend="test", tflops=100.0):
    return TuneRecord(space=space, inputs=inputs,
                      config=dict(cfg or CFG), tflops=tflops, backend=backend)


# ---------------------------------------------------------------------------
# telemetry layer: export -> aggregate -> merge equivalence
# ---------------------------------------------------------------------------

def test_export_aggregate_merge_equivalence_under_concurrent_writers(
        tmp_path):
    """save -> dump -> aggregate must equal the in-process counts exactly,
    even when the dumps are written while ring writers are still landing."""
    bus = tmp_path / "telemetry"
    replicas = [ShapeTelemetry() for _ in range(3)]
    n_threads, n_each = 4, 200

    def writer(tel, tid):
        for j in range(n_each):
            tel.record_buffered("gemm", _shape((tid + j) % 5))

    exporters = [TelemetryExporter(tel, bus, worker_id=f"w{i}")
                 for i, tel in enumerate(replicas)]
    threads = [threading.Thread(target=writer, args=(tel, tid))
               for tel in replicas for tid in range(n_threads)]
    for th in threads:
        th.start()
    # export concurrently with the writers: a dump is a consistent prefix
    for exp in exporters:
        exp.export_once()
    for th in threads:
        th.join()
    # final cumulative dump per replica now holds the complete counts
    for exp in exporters:
        exp.export_once()

    view = FleetTelemetryView(bus, local=ShapeTelemetry(), refresh_s=0.0)
    assert view.total() == 3 * n_threads * n_each
    for i in range(5):
        want = sum(tel.count("gemm", _shape(i)) for tel in replicas)
        assert view.count("gemm", _shape(i)) == want

    # the same equivalence through plain ShapeTelemetry.merge of the dumps
    merged = ShapeTelemetry()
    for wdir in sorted(bus.iterdir()):
        latest = sorted(wdir.glob("*.json"))[-1]
        merged.merge(ShapeTelemetry.load(latest))
    assert merged.total() == view.total()
    for i in range(5):
        assert merged.count("gemm", _shape(i)) == view.count(
            "gemm", _shape(i))


def test_cumulative_dumps_never_double_count(tmp_path):
    """Only the LATEST epoch per worker folds in: re-exporting a grown
    telemetry must not add the old dump's counts on top."""
    bus = tmp_path / "telemetry"
    tel = ShapeTelemetry()
    exp = TelemetryExporter(tel, bus, worker_id="w0", keep=2)
    tel.record("gemm", _shape(0), n=10)
    exp.export_once()
    tel.record("gemm", _shape(0), n=5)
    exp.export_once()
    view = FleetTelemetryView(bus, local=ShapeTelemetry(), refresh_s=0.0)
    assert view.count("gemm", _shape(0)) == 15
    # pruning keeps the bus O(workers): `keep` newest epochs survive
    tel.record("gemm", _shape(0), n=1)
    exp.export_once()
    files = sorted((bus / "w0").glob("*.json"))
    assert len(files) == 2
    assert [f.stem for f in files] == ["00000002", "00000003"]


def test_torn_dump_falls_back_to_older_epoch(tmp_path):
    bus = tmp_path / "telemetry"
    tel = ShapeTelemetry()
    tel.record("gemm", _shape(0), n=7)
    exp = TelemetryExporter(tel, bus, worker_id="w0", keep=3)
    exp.export_once()
    tel.record("gemm", _shape(0), n=3)
    torn = exp.export_once()
    torn.write_text("{not json")               # simulated torn write
    view = FleetTelemetryView(bus, local=ShapeTelemetry(), refresh_s=0.0)
    assert view.count("gemm", _shape(0)) == 7   # older epoch served
    prov = view.replicas()
    assert prov["w0"]["epoch"] == 1


def test_view_merges_local_and_excludes_own_dump(tmp_path):
    """A process that both exports and aggregates must not fold its own
    live counts in twice (live local + its own stale dump)."""
    bus = tmp_path / "telemetry"
    local = ShapeTelemetry()
    local.record("gemm", _shape(0), n=4)
    TelemetryExporter(local, bus, worker_id="me").export_once()
    other = ShapeTelemetry()
    other.record("gemm", _shape(0), n=6)
    TelemetryExporter(other, bus, worker_id="peer").export_once()

    view = FleetTelemetryView(bus, local=local, refresh_s=0.0,
                              exclude={"me"})
    assert view.count("gemm", _shape(0)) == 10    # 4 live + 6 peer, not 14
    assert set(view.replicas()) == {"peer"}
    st = view.stats()
    assert st["scope"] == "fleet"
    assert st["replicas"]["peer"]["calls"] == 6


def test_coordinator_global_view_and_provenance_roundtrip(tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    bus = coord.fleet.telemetry_dir()
    for i in range(3):
        tel = ShapeTelemetry()
        tel.record("gemm", _shape(i), n=10 * (i + 1))
        exp = TelemetryExporter(tel, bus, worker_id=f"w{i}")
        exp.export_once()
        exp.export_once()                     # provenance tracks epoch 2
    view = coord.global_telemetry()
    assert view.total() == 60
    prov = coord.telemetry_provenance()
    assert set(prov) == {"w0", "w1", "w2"}
    for i in range(3):
        assert prov[f"w{i}"]["epoch"] == 2
        assert prov[f"w{i}"]["calls"] == 10 * (i + 1)
        assert prov[f"w{i}"]["age_s"] >= 0.0
    # plan_from_telemetry defaults to the fleet-global view
    jobs = coord.plan_from_telemetry(top_k=8)
    assert {tuple(sorted(j.inputs.items())) for j in jobs} == {
        tuple(sorted(_shape(i).items())) for i in range(3)}


def test_controller_triggers_only_off_aggregated_fleet_mass(tmp_path):
    """The tentpole's acceptance demo: three replicas each sit below
    min_calls, so a process-local controller never triggers — the
    fleet-global controller sees their sum and does."""
    bus = tmp_path / "telemetry"
    store = RecordStore()
    install_store(store)                      # no records: all mass untuned
    local = ShapeTelemetry()
    cfg = RetuneConfig(min_calls=32, untuned_mass_threshold=0.5)

    fleet_view = FleetTelemetryView(bus, local=local, refresh_s=0.0)
    ctl_fleet = RetuneController(store, telemetry=fleet_view, cfg=cfg)
    ctl_local = RetuneController(store, telemetry=local, cfg=cfg)
    assert ctl_fleet.stats()["telemetry_scope"] == "fleet"
    assert ctl_local.stats()["telemetry_scope"] == "process"

    local.record("gemm", _shape(0), n=5)      # this replica's own window
    for i in range(3):                        # three peers, 15 calls each
        tel = ShapeTelemetry()
        tel.record("gemm", _shape(0), n=15)
        TelemetryExporter(tel, bus, worker_id=f"peer{i}").export_once()

    dec_local = ctl_local.check()["gemm"]
    assert not dec_local.trigger              # 5 < min_calls: under-informed
    dec_fleet = ctl_fleet.check()["gemm"]
    assert dec_fleet.window_calls == 50       # 5 local + 3*15 aggregated
    assert dec_fleet.trigger and dec_fleet.reason in ("drift", "untuned")


# ---------------------------------------------------------------------------
# specialization layer: affinity classes -> per-replica plans
# ---------------------------------------------------------------------------

def test_partition_hot_shapes_balances_bucket_mass(tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    tel = ShapeTelemetry()
    # two heavy log2 buckets + two light ones; same-bucket shapes must
    # travel together, and mass must spread over both replicas
    tel.record("gemm", gemm_input(4096, 64, 512), n=100)
    tel.record("gemm", gemm_input(4097, 64, 512), n=80)    # same bucket
    tel.record("gemm", gemm_input(256, 64, 512), n=90)
    tel.record("gemm", gemm_input(16, 64, 512), n=10)
    classes = coord.partition_hot_shapes(2, telemetry=tel, top_k=8)
    assert sum(len(c) for c in classes) == 4
    masses = [sum(n for _, _, n in c) for c in classes]
    assert sorted(masses) == [100, 180]       # LPT: [4096-bucket], [rest]
    for cls in classes:
        buckets = {coord._shape_bucket(s, i) for s, i, _ in cls}
        if any(i["M"] in (4096, 4097) for _, i, _ in cls):
            assert len(buckets) == 1          # the heavy bucket stays whole


def test_publish_replica_plans_are_small_and_specialized(tmp_path):
    store = RecordStore.open(tmp_path / "db.jsonl")
    shapes = [gemm_input(4096, 64, 512), gemm_input(256, 64, 512)]
    for s in shapes:
        store.add(_rec(s))
    coord = Coordinator(tmp_path / "fleet", store)
    tel = ShapeTelemetry()
    tel.record("gemm", shapes[0], n=100)
    tel.record("gemm", shapes[1], n=90)
    root = tmp_path / "registries"
    out = coord.publish_replica_plans(root, 2, telemetry=tel,
                                      fingerprint="test")
    assert [o["replica"] for o in out] == ["replica-0", "replica-1"]
    assert all(o["entries"] == 1 for o in out)     # SMALL: one class each

    plans = []
    for o in out:
        reg = PlanRegistry(o["registry"])
        pointer = reg.current()
        assert pointer is not None and pointer["generation"] == \
            o["generation"]
        plans.append(reg.pull(pointer))
    covered = set()
    for p in plans:
        assert len(p) == 1
        for s in shapes:
            if p.lookup("gemm", shape_key(s)) is not None:
                covered.add(tuple(sorted(s.items())))
        # each replica plan misses the OTHER replica's class
        assert sum(plan_coverage(p, [("gemm", s)]) for s in shapes) == 1.0
    assert len(covered) == 2                  # together they cover the set


# ---------------------------------------------------------------------------
# routing layer
# ---------------------------------------------------------------------------

def _plan_for(shapes):
    tbl = {("gemm", shape_key(s)): (dict(CFG), "exact") for s in shapes}
    return DispatchPlan(generation=0, fingerprint="test", store_version=-1,
                        table=tbl)


def test_affinity_router_lands_requests_on_covering_replica():
    r = ShapeAffinityRouter()
    r.add_replica("a", plan=_plan_for([_shape(0)]))
    r.add_replica("b", plan=_plan_for([_shape(1)]))
    for _ in range(3):
        assert r.route([("gemm", _shape(1))]).name == "b"
        assert r.route([("gemm", _shape(0))]).name == "a"
    st = r.stats()
    assert st["policy"] == "affinity"
    assert st["outcomes"] == {"affinity": 6}
    assert {x["name"]: x["assigned"] for x in st["replicas"]} == \
        {"a": 3, "b": 3}


def test_affinity_router_load_bound_and_escape():
    r = ShapeAffinityRouter(max_imbalance=2.0)
    ra = r.add_replica("a", plan=_plan_for([_shape(0), _shape(1)]))
    r.add_replica("b", plan=_plan_for([_shape(1)]))
    # a fully covers the request, b half-covers it; once a is
    # max_imbalance ahead it is ineligible and b takes the request as a
    # "balanced" decision (partial coverage beats nothing)
    req = [("gemm", _shape(0)), ("gemm", _shape(1))]
    names = [r.route(req).name for _ in range(6)]
    assert "b" in names                           # the bound kicked in
    assert r.outcomes.get("balanced", 0) > 0
    assert ra.assigned + names.count("b") == 6    # every request landed once
    # a request class NO plan covers still gets served (escape hatch)
    picked = r.route([("gemm", _shape(4))])
    assert picked is not None
    assert r.outcomes.get("escape", 0) == 1


def test_router_no_starvation_under_skewed_workload():
    """Zero starved request class: every class keeps being served even when
    one replica covers the entire hot set."""
    r = ShapeAffinityRouter(max_imbalance=4.0)
    r.add_replica("hot", plan=_plan_for([_shape(i) for i in range(4)]))
    r.add_replica("cold", plan=None)
    served = {i: 0 for i in range(5)}             # class 4 is uncovered
    for step in range(100):
        cls = step % 5
        served[cls] += 1 if r.route([("gemm", _shape(cls))]) else 0
    assert all(v == 20 for v in served.values())
    loads = {x.name: x.assigned for x in r.replicas}
    assert abs(loads["hot"] - loads["cold"]) <= 4.0 + 1


def test_baseline_routers_and_factory():
    rr = make_router("round_robin")
    assert isinstance(rr, RoundRobinRouter)
    rr.add_replica("a")
    rr.add_replica("b")
    assert [rr.route().name for _ in range(4)] == ["a", "b", "a", "b"]
    assert rr.stats()["outcomes"] == {"baseline": 4}

    rnd = make_router("random")
    assert isinstance(rnd, RandomRouter)
    rnd.add_replica("a")
    rnd.add_replica("b")
    assert {rnd.route().name for _ in range(20)} == {"a", "b"}

    assert isinstance(make_router("affinity"), ShapeAffinityRouter)
    with pytest.raises(ValueError, match="unknown router policy"):
        make_router("bogus")
    with pytest.raises(RuntimeError, match="no replicas"):
        make_router("affinity").route([])


def test_plan_coverage_fractions():
    plan = _plan_for([_shape(0), _shape(1)])
    assert plan_coverage(plan, [("gemm", _shape(0))]) == 1.0
    assert plan_coverage(plan, [("gemm", _shape(0)),
                                ("gemm", _shape(3))]) == 0.5
    assert plan_coverage(None, [("gemm", _shape(0))]) == 0.0
    assert plan_coverage(plan, []) == 0.0


def test_fleet_route_cli_picks_covering_replica(tmp_path, capsys):
    from repro.tunedb.__main__ import main as tunedb_main

    store = RecordStore.open(tmp_path / "db.jsonl")
    shapes = [gemm_input(4096, 64, 512), gemm_input(256, 64, 512)]
    for s in shapes:
        store.add(_rec(s))
    coord = Coordinator(tmp_path / "fleet", store)
    tel = ShapeTelemetry()
    tel.record("gemm", shapes[0], n=100)
    tel.record("gemm", shapes[1], n=90)
    root = tmp_path / "registries"
    out = coord.publish_replica_plans(root, 2, telemetry=tel,
                                      fingerprint="test")
    covering = {o["replica"]: o for o in out}
    assert set(covering) == {"replica-0", "replica-1"}

    rc = tunedb_main(["fleet", "route", "--registry-root", str(root),
                      "--space", "gemm", "--shape", "M=4096,N=64,K=512"])
    assert rc == 0
    got = json.loads(capsys.readouterr().out)
    assert got["policy"] == "affinity"
    assert got["outcome"] == "affinity"
    assert got["coverage"][got["replica"]] == 1.0
    other = next(n for n in covering if n != got["replica"])
    assert got["coverage"][other] == 0.0


# ---------------------------------------------------------------------------
# satellites: snapshot ring drain, tuned decode splits
# ---------------------------------------------------------------------------

def test_status_snapshot_drains_pending_rings():
    """/status and `tunedb stats --json` must never under-report: counts
    still sitting in per-thread rings are drained before serializing."""
    tel = get_telemetry()
    for _ in range(9):
        tel.record_buffered("gemm", _shape(0))
    snap = status_snapshot()
    assert snap["telemetry"]["spaces"]["gemm"]["calls"] == 9

    # same through an explicit fleet view (duck-typed drain of the local leg)
    for _ in range(4):
        tel.record_buffered("gemm", _shape(0))
    view = FleetTelemetryView("/nonexistent", local=tel, refresh_s=0.0)
    snap = status_snapshot(telemetry=view)
    assert snap["telemetry"]["spaces"]["gemm"]["calls"] == 13
    assert snap["telemetry"]["scope"] == "fleet"


def test_engine_wires_export_router_and_status(tmp_path):
    """End-to-end engine wiring: telemetry dumps land on the fleet bus, the
    controller reads the fleet-scope view, every admitted request takes a
    routing decision, and /status carries the router section."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.models import ModelConfig, init_params
    from repro.serve import Engine, ServeConfig

    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    RecordStore.open(tmp_path / "db.jsonl").add(_rec(_shape(0)))
    eng = Engine(cfg, params, ServeConfig(
        max_len=64, slots=2, retune=True, retune_interval=4,
        tunedb=str(tmp_path / "db.jsonl"),
        retune_fleet=str(tmp_path / "fleet"), telemetry_export_s=0.05,
        router="affinity", status_port=0))
    assert eng.exporter is not None and eng.router is not None
    assert eng.controller.stats()["telemetry_scope"] == "fleet"

    rng = np.random.default_rng(0)
    outs = eng.generate([rng.integers(0, 128, 6) for _ in range(4)],
                        max_new=6)
    assert all(len(o) == 6 for o in outs)
    assert eng.router.stats()["decisions"] >= 4      # one per admission
    eng.exporter.stop()                              # final dump flushes
    dumps = list((tmp_path / "fleet" / "telemetry"
                  / eng.exporter.worker_id).glob("*.json"))
    assert dumps, "engine exporter never dumped to the fleet bus"
    snap = eng.status_server.status_json()
    assert snap["router"]["policy"] == "affinity"
    assert snap["router"]["replicas"][0]["name"] == "local"
    assert snap["retune"]["telemetry_scope"] == "fleet"
    # per-replica dump provenance surfaces in the fleet section even
    # before any `fleet start` writes a manifest to the bus
    assert eng.exporter.worker_id in snap["fleet"]["telemetry_replicas"]
    eng.status_server.stop()


def test_resolve_decode_splits_tuned_and_fallback():
    from repro.serve.flash_decode import resolve_decode_splits

    kw = dict(B=1, Hq=8, Hkv=2, Lkv=2048, D=64, dtype_bits=16)
    # untuned process: exact prior behavior — the caller's value
    assert resolve_decode_splits(default=8, **kw) == 8
    # ...and the probe itself feeds telemetry (hot-shape mining sees it)
    tel = get_telemetry()
    tel.drain_pending()
    assert tel.total("attention") >= 1

    inputs = {"B": 1, "Hq": 8, "Hkv": 2, "Lq": 1, "Lkv": 2048, "D": 64,
              "dtype_bits": 16, "causal": 1}
    store = RecordStore()
    store.add(TuneRecord(space="attention", inputs=inputs,
                         config=dict(ATTN_CFG), tflops=50.0, backend="test"))
    install_store(store)
    assert serving_state().store is store
    # tuned: n_splits = Lkv // b_kv from the resolved attention config
    assert resolve_decode_splits(default=8, **kw) == 2048 // 512
    # a tuned block that does not tile Lkv falls back to the caller's value
    assert resolve_decode_splits(default=3, **dict(kw, Lkv=1000)) == 3
