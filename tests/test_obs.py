"""Observability layer (PR 6): metrics registry, status endpoint, sentry.

Pins the tentpole contracts: the per-thread-sharded registry loses no
increments under threaded writers; /metrics + /status + /plan round-trip
against a live engine (one serializer shared with the --json CLIs, so the
schemas cannot drift); the regression sentry catches an injected regressed
record and makes ``install_serving`` refuse the swap (and the fleet
coordinator refuse the merge); the dispatch degradation warn-once latch
still warns once but counts EVERY occurrence; and admission bucket()
decisions land in the registry.
"""

import json
import threading
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.space import gemm_input
from repro.core.tuner import clear_tuners
from repro.kernels import dispatch
from repro.models import ModelConfig, init_params
from repro.serve import Engine, ServeConfig
from repro.serve.engine import StoreAwareAdmission
from repro.tunedb import (RecordStore, TuneRecord, clear_store,
                          clear_telemetry, install_serving, install_store,
                          serving_state)
from repro.tunedb.obs import (RegressionSentry, StatusServer, plan_snapshot,
                              status_snapshot)
from repro.tunedb.obs.metrics import get_registry, reset_metrics

CFG = {"bm": 64, "bn": 128, "bk": 128, "k_unroll": 1, "k_split": 1,
       "order": 0, "acc32": 1, "prefetch": 2}


@pytest.fixture(autouse=True)
def _clean_globals():
    def reset():
        from repro.tunedb.model import clear_models
        clear_tuners()
        clear_store()
        clear_models()
        clear_telemetry()
        dispatch.reset_fallback_warnings()
        reset_metrics()
    reset()
    yield
    reset()


def _rec(m, n, k, *, backend="test", tflops=100.0, source="tuner",
         created_at=0.0, **cfg_over):
    return TuneRecord(space="gemm", inputs=gemm_input(m, n, k),
                      config=dict(CFG, **cfg_over), tflops=tflops,
                      backend=backend, source=source, created_at=created_at)


def _get(url):
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.read().decode()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_counter_threaded_writers_lose_no_increments():
    reg = get_registry()
    counter = reg.counter("obs_test_total", "threaded increments")
    n_threads, per_thread = 8, 5000

    def worker():
        for _ in range(per_thread):
            counter.inc(space="gemm")

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert counter.value(space="gemm") == n_threads * per_thread


def test_counter_survives_dead_writer_threads():
    counter = get_registry().counter("obs_dead_total")
    t = threading.Thread(target=lambda: counter.inc(7))
    t.start()
    t.join()
    # the dead thread's shard folds into the base on read — twice, to prove
    # the fold does not double-count
    assert counter.value() == 7
    assert counter.value() == 7


def test_histogram_ring_quantiles_and_prometheus_render():
    reg = get_registry()
    hist = reg.histogram("obs_lat_seconds", "latency")
    for i in range(1, 101):
        hist.observe(float(i))
    q = hist.quantiles()
    assert q[0.5] == pytest.approx(50, abs=2)
    assert q[0.99] == pytest.approx(99, abs=2)
    text = reg.render_prometheus()
    assert "# TYPE obs_lat_seconds summary" in text
    assert 'obs_lat_seconds{quantile="0.5"}' in text
    assert "obs_lat_seconds_count 100" in text
    assert "obs_lat_seconds_sum 5050" in text


def test_collectors_surface_tier_metrics_with_zero_dispatch_wiring():
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    install_store(store)
    dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048))    # exact/plan hit
    # the generation counter is process-global and monotonic — pin the
    # assertions to its actual value, not a literal
    gen = serving_state().generation
    text = get_registry().render_prometheus()
    assert 'tunedb_store_lookups_total{tier="exact"} 1\n' in text
    assert f"tunedb_serving_generation {gen}\n" in text
    assert f"tunedb_plan_generation {gen}\n" in text
    assert 'tunedb_plan_entries{origin="built"} 1\n' in text


# ---------------------------------------------------------------------------
# degradation counting (the warn-once bugfix)
# ---------------------------------------------------------------------------

def test_degraded_calls_warn_once_but_count_every_occurrence():
    install_store(RecordStore())          # empty store: every shape degrades
    with pytest.warns(RuntimeWarning, match="no record, model, or neighbor"):
        dispatch._tuned_cfg("gemm", gemm_input(96, 96, 96))
    # subsequent degradations are silent (the latch) but still counted
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")   # a second warn would fail the test
        dispatch._tuned_cfg("gemm", gemm_input(96, 96, 96))
        dispatch._tuned_cfg("gemm", gemm_input(96, 96, 96))
    counter = get_registry().counter("tunedb_dispatch_degraded_calls_total")
    assert counter.value(reason="untuned", space="gemm") == 3


# ---------------------------------------------------------------------------
# sentry
# ---------------------------------------------------------------------------

def test_sentry_catches_injected_regression_and_install_refuses():
    store = RecordStore()
    store.add(_rec(512, 16, 2048, tflops=80.0))
    st1 = install_serving(store=store)
    # inject a regressed record: same key, newer, far beyond the margin
    store.add(_rec(512, 16, 2048, tflops=40.0, bm=128))
    sentry = RegressionSentry(noise_margin=0.10)
    report = sentry.check_supersessions(
        store, since_version=st1.plan.store_version)
    assert not report.ok and len(report.regressions) == 1
    assert report.regressions[0].drop == pytest.approx(0.5)
    with pytest.warns(RuntimeWarning, match="sentry refused"):
        st2 = install_serving(store=store, sentry=sentry)
    assert st2.generation == st1.generation        # swap refused
    assert serving_state() is st1
    # the same install without the sentry promotes the regression
    st3 = install_serving(store=store)
    assert st3.generation == st1.generation + 1


def test_sentry_within_noise_margin_promotes():
    store = RecordStore()
    store.add(_rec(512, 16, 2048, tflops=80.0))
    st1 = install_serving(store=store)
    store.add(_rec(512, 16, 2048, tflops=78.0))    # 2.5% — inside 10% noise
    st2 = install_serving(store=store, sentry=RegressionSentry(0.10))
    assert st2.generation == st1.generation + 1


def test_sentry_diffs_two_stores(tmp_path):
    old = RecordStore(tmp_path / "old.jsonl")
    new = RecordStore(tmp_path / "new.jsonl")
    old.add(_rec(512, 16, 2048, tflops=80.0))
    new.add(_rec(512, 16, 2048, tflops=40.0))
    old.add(_rec(1024, 16, 2048, tflops=70.0))
    new.add(_rec(1024, 16, 2048, tflops=75.0))
    report = RegressionSentry(0.10).diff_stores(old, new)
    assert report.checked == 2 and report.improved == 1
    assert len(report.regressions) == 1
    assert report.regressions[0].inputs["M"] == 512
    # install gate on a DIFFERENT store object takes the diff path
    install_serving(store=old)
    with pytest.warns(RuntimeWarning, match="sentry refused"):
        st = install_serving(store=new, sentry=RegressionSentry(0.10))
    assert st.store is old


def test_coordinator_merge_refuses_regressed_shard_record(tmp_path):
    from repro.tunedb.fleet import Coordinator
    store = RecordStore(tmp_path / "parent.jsonl")
    store.add(_rec(512, 16, 2048, tflops=80.0))
    coord = Coordinator(tmp_path / "fleet", store, sentry_margin=0.10)
    shard_dir = coord.fleet.shard_dir()
    shard_dir.mkdir(parents=True, exist_ok=True)
    shard = RecordStore(shard_dir / "w1.jsonl")
    newer = store._index[("test", _rec(512, 16, 2048).key)].created_at + 1
    shard.add(_rec(512, 16, 2048, tflops=40.0, created_at=newer, bm=128))
    shard.add(_rec(2048, 16, 2048, tflops=90.0, created_at=newer))
    n_recs, _ = coord.merge_completed()
    assert n_recs == 1                              # the clean record only
    assert coord.sentry_blocked == 1
    kept = store._index[("test", _rec(512, 16, 2048).key)]
    assert kept.tflops == 80.0                      # regression never landed
    assert store.contains("gemm", gemm_input(2048, 16, 2048))
    assert coord.report(write=False).sentry_blocked == 1


# ---------------------------------------------------------------------------
# endpoint round-trip against a live engine
# ---------------------------------------------------------------------------

def test_status_endpoint_roundtrip_live_engine(tmp_path):
    store = RecordStore(tmp_path / "tunedb.jsonl")
    store.add(_rec(512, 16, 2048, backend="warm"))
    cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                      d_ff=128, vocab=128, dtype=jnp.float32, attn_chunk=16,
                      logit_chunk=16, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(
        max_len=64, slots=2, tunedb=str(tmp_path / "tunedb.jsonl"),
        status_port=0))
    assert eng.status_server is not None and eng.status_server.port > 0
    try:
        rng = np.random.default_rng(0)
        eng.generate([rng.integers(0, 128, 6) for _ in range(3)], max_new=4)
        # off-TPU the model path records telemetry but skips config
        # resolution — drive one resolution so the tier counters light up
        # the way every TPU kernel call would
        dispatch._tuned_cfg("gemm", gemm_input(512, 16, 2048))
        base = eng.status_server.url
        status = json.loads(_get(base + "/status"))
        assert status["schema"] == 1
        assert status["serving"]["generation"] >= 1
        assert status["serving"]["plan"]["entries"] >= 1
        assert set(status["tiers"]["rates"]) == {"exact", "nearest",
                                                 "model", "miss"}
        # live traffic lands on the frozen-plan probe before any store
        # tier is consulted, so the plan counters carry the call volume
        plan_stats = status["tiers"]["plan"]
        assert plan_stats["hits"] + plan_stats["misses"] > 0
        assert status["telemetry"]["spaces"]        # dispatch fed telemetry
        metrics = _get(base + "/metrics")
        assert "tunedb_serving_generation" in metrics
        assert "tunedb_store_lookups_total" in metrics
        plan = json.loads(_get(base + "/plan"))
        assert plan["generation"] == status["serving"]["generation"]
        assert any(e["tier"] == "exact" for e in plan["entries"])
        assert _get(base + "/healthz").strip() == "ok"
    finally:
        eng.status_server.stop()


def test_cli_json_shares_the_status_schema(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    store_path = tmp_path / "s.jsonl"
    RecordStore(store_path).add(_rec(512, 16, 2048))
    assert main(["stats", "--store", str(store_path), "--json"]) == 0
    cli_doc = json.loads(capsys.readouterr().out)
    http_doc = status_snapshot(store=RecordStore(store_path))
    assert set(cli_doc) == set(http_doc)            # one serializer, no drift
    assert cli_doc["serving"]["store"]["records"] == 1


def test_fleet_status_json_uses_the_shared_schema(tmp_path, capsys):
    from repro.tunedb.fleet import Coordinator
    from repro.tunedb.__main__ import main
    store = RecordStore(tmp_path / "parent.jsonl")
    coord = Coordinator(tmp_path / "fleet", store)
    coord.report(wall_s=1.0)
    assert main(["fleet", "status", "--fleet", str(tmp_path / "fleet"),
                 "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert set(doc) == set(status_snapshot(fleet=str(tmp_path / "fleet")))
    assert doc["fleet"]["counts"]["queue"] == 0
    assert doc["fleet"]["report"]["sentry_blocked"] == 0
    # --watch prints compact progress lines off the same snapshot
    assert main(["fleet", "status", "--fleet", str(tmp_path / "fleet"),
                 "--watch", "--max-polls", "2", "--interval", "0.01"]) == 0
    lines = capsys.readouterr().out.strip().splitlines()
    assert len(lines) == 2 and all(l.startswith("[fleet] queue=0 ")
                                   for l in lines)


# ---------------------------------------------------------------------------
# diff CLI
# ---------------------------------------------------------------------------

def _two_stores(tmp_path):
    old = RecordStore(tmp_path / "old.jsonl")
    new = RecordStore(tmp_path / "new.jsonl")
    old.add(_rec(512, 16, 2048, tflops=80.0))
    new.add(_rec(512, 16, 2048, tflops=40.0, bm=128))
    return str(tmp_path / "old.jsonl"), str(tmp_path / "new.jsonl")


def test_diff_cli_exits_nonzero_on_regression(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    old, new = _two_stores(tmp_path)
    assert main(["diff", old, new]) == 1
    out = capsys.readouterr().out
    assert "REGRESSED gemm" in out and "80.00 -> 40.00" in out
    assert "verdict: 1 regression(s)" in out
    assert main(["diff", old, old]) == 0            # self-diff is clean
    assert "verdict: OK" in capsys.readouterr().out


def test_diff_cli_json_golden(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    old, new = _two_stores(tmp_path)
    assert main(["diff", old, new, "--json"]) == 1
    doc = json.loads(capsys.readouterr().out)
    assert doc["ok"] is False and doc["checked"] == 1
    [reg] = doc["regressions"]
    assert reg["space"] == "gemm" and reg["drop"] == pytest.approx(0.5)
    assert reg["old_tflops"] == 80.0 and reg["new_tflops"] == 40.0
    # a wider noise margin absorbs the same delta
    assert main(["diff", old, new, "--margin", "0.6"]) == 0


def test_diff_cli_plan_snapshots_flag_coverage_loss(tmp_path, capsys):
    from repro.tunedb.__main__ import main
    store = RecordStore()
    store.add(_rec(512, 16, 2048))
    store.add(_rec(1024, 16, 2048))
    install_serving(store=store)
    big = plan_snapshot()
    clear_store()
    store2 = RecordStore()
    store2.add(_rec(512, 16, 2048))
    install_serving(store=store2)
    small = plan_snapshot()
    p_old, p_new = tmp_path / "old.json", tmp_path / "new.json"
    p_old.write_text(json.dumps(big))
    p_new.write_text(json.dumps(small))
    assert main(["diff", str(p_old), str(p_new)]) == 1
    assert "DROPPED gemm" in capsys.readouterr().out
    assert main(["diff", str(p_new), str(p_old)]) == 0   # growth is fine


# ---------------------------------------------------------------------------
# admission decisions in the registry
# ---------------------------------------------------------------------------

def test_admission_bucket_decisions_are_recorded():
    store = RecordStore()
    store.add(_rec(512, 64, 1024, bm=512, bn=64, tflops=60.0))
    store.add(_rec(1024, 64, 1024, bm=512, bn=64, tflops=100.0))
    install_store(store)
    adm = StoreAwareAdmission()
    _, d1 = adm.bucket("gemm", gemm_input(530, 64, 1024))
    _, d2 = adm.bucket("gemm", gemm_input(500, 64, 1024))
    _, d3 = adm.bucket("gemm", gemm_input(512, 64, 1024))
    assert (d1, d2, d3) == ("padded", "exact", "hit")
    counter = get_registry().counter("tunedb_admission_decisions_total")
    for decision in ("padded", "exact", "hit"):
        assert counter.value(space="gemm", decision=decision) == 1


def test_retune_history_lands_in_controller_stats():
    from repro.tunedb.controller import RetuneConfig, RetuneController
    store = RecordStore()
    install_store(store)
    ctl = RetuneController(store, cfg=RetuneConfig(min_calls=1))
    ctl.maybe_retune(decisions={})       # no triggers: closes no epoch
    assert ctl.stats()["history"] == []
    assert ctl.stats()["sentry_blocked"] == 0
