"""Input-aware autotuning OF A WHOLE MODEL — the paper's §6 'kernel
generation backend' used the way a serving/training stack would:

1. walk an assigned architecture config and collect every distinct GEMM
   signature its forward pass executes (qkv/o projections, mlp, experts,
   logits) for a given batch geometry;
2. run the tuner once per signature (exhaustive inference over the MLP) and
   persist the chosen kernel configs to the filesystem cache;
3. install the tuner so `kernels.dispatch` serves every model matmul with
   its input-aware kernel.

    PYTHONPATH=src python examples/autotune_model.py --arch dbrx-132b
"""

import argparse
from typing import Dict, List, Tuple

from repro.configs import ARCH_NAMES, get_config
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import InputAwareTuner, install_tuner


def gemm_signatures(cfg, batch: int, seq: int) -> List[Tuple[str, Dict]]:
    """Every distinct (M, N, K) the arch's forward pass runs."""
    T = batch * seq
    d, hd = cfg.d_model, cfg.hd
    sigs = []
    if cfg.n_heads:
        sigs += [
            ("wq", gemm_input(T, cfg.n_heads * hd, d)),
            ("wk/wv", gemm_input(T, cfg.n_kv * hd, d)),
            ("wo", gemm_input(T, d, cfg.n_heads * hd)),
        ]
    if cfg.d_ff:
        sigs += [("mlp gate/up", gemm_input(T, cfg.d_ff, d)),
                 ("mlp down", gemm_input(T, d, cfg.d_ff))]
    if cfg.n_experts:
        cap = seq * cfg.top_k * int(cfg.capacity_factor) // cfg.n_experts + 1
        sigs += [("expert ffn (per-expert)",
                  gemm_input(batch * cap, cfg.d_ff, d))]
    if cfg.ssm_state:
        di = 2 * d
        nh = di // cfg.ssm_head_dim
        sigs += [("mamba in-proj",
                  gemm_input(T, 2 * di + 2 * cfg.ssm_state + nh, d)),
                 ("mamba out-proj", gemm_input(T, d, di))]
    sigs += [("logits", gemm_input(T, cfg.padded_vocab, d))]
    return sigs


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", choices=ARCH_NAMES, default="dbrx-132b")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--cache-dir", default="/tmp/repro-isaac-cache")
    args = p.parse_args()

    cfg = get_config(args.arch)
    print("== tuner training (once per device generation) ==")
    tuner = InputAwareTuner.train(GEMM_SPACE, n_samples=6000,
                                  hidden=(64, 128, 64), epochs=20,
                                  cache_dir=args.cache_dir)
    install_tuner(tuner)

    print(f"\n== tuning every GEMM of {cfg.name} "
          f"(batch={args.batch}, seq={args.seq}) ==")
    for name, inputs in gemm_signatures(cfg, args.batch, args.seq):
        best = tuner.best_config(inputs)          # cached on disk
        res = tuner.search(inputs, remeasure=False)
        print(f"{name:26s} M={inputs['M']:7d} N={inputs['N']:6d} "
              f"K={inputs['K']:6d} -> bm={best['bm']:4d} bn={best['bn']:4d} "
              f"bk={best['bk']:4d} k_split={best['k_split']:2d}  "
              f"(~{res.predicted_tflops:5.1f} TFLOPS predicted)")
    print(f"\nconfigs cached under {args.cache_dir} — subsequent runs of "
          f"any model with these shapes skip inference entirely.")


if __name__ == "__main__":
    main()
