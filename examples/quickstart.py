"""Quickstart: the paper's full pipeline in one page.

    PYTHONPATH=src python examples/quickstart.py

1. define the GEMM tuning space (paper §3);
2. fit the categorical generative model and draw LEGAL configs (paper §4);
3. label them with the measurement backend and train the MLP (paper §5);
4. runtime inference: fix the input, search the model exhaustively, re-measure
   the top-k, cache the winner (paper §6).
"""


from repro.core.backend import SimulatedTPUBackend
from repro.core.space import GEMM_SPACE, gemm_input
from repro.core.tuner import InputAwareTuner, install_tuner

print("== training the input-aware tuner (small budget for the demo) ==")
tuner = InputAwareTuner.train(
    GEMM_SPACE, n_samples=6000, hidden=(64, 128, 64), epochs=20,
    backend=SimulatedTPUBackend(noise=0.03), verbose=True)

print("\n== runtime inference on unseen input shapes ==")
for m, n, k, desc in [
        (2048, 2048, 2048, "LINPACK square"),
        (2560, 16, 2560, "DeepBench skinny-N"),
        (64, 64, 60000, "ICA deep reduction"),
        (4096, 4096, 32, "LAPACK outer product")]:
    inputs = gemm_input(m, n, k)
    res = tuner.search(inputs)
    cfg = {kk: res.best[kk] for kk in ("bm", "bn", "bk", "k_split")}
    print(f"{desc:24s} M={m:5d} N={n:5d} K={k:6d} -> {cfg}  "
          f"predicted {res.predicted_tflops:6.1f}  "
          f"measured {res.measured_tflops:6.1f} TFLOPS  "
          f"({res.n_candidates} candidates scored in one MLP batch)")

print("\n== install as the kernel-dispatch backend (models pick it up) ==")
install_tuner(tuner)
import jax.numpy as jnp
from repro.kernels import dispatch
a = jnp.ones((256, 512), jnp.float32)
b = jnp.ones((512, 128), jnp.float32)
out = dispatch.matmul(a, b, prefer_kernel=True)   # tuned Pallas (interpret)
print("dispatch.matmul through the tuned Pallas kernel:", out.shape,
      "ok" if bool((out == 512).all()) else "MISMATCH")
