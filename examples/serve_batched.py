"""Batched serving example: continuous-batching engine over a small LM.

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import init_params
from repro.serve import Engine, ServeConfig

cfg = smoke_config("glm4-9b")
params = init_params(cfg, jax.random.PRNGKey(0))
engine = Engine(cfg, params, ServeConfig(max_len=128, slots=4))

rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab, int(n))
           for n in rng.integers(8, 24, size=10)]

t0 = time.perf_counter()
outs = engine.generate(prompts, max_new=24)
dt = time.perf_counter() - t0

tok = sum(len(o) for o in outs)
print(f"{len(prompts)} requests (lens {[len(p) for p in prompts]})")
print(f"{tok} tokens in {dt:.2f}s = {tok/dt:.1f} tok/s; "
      f"{engine.ticks} decode ticks -> {tok/engine.ticks:.2f} tokens/tick "
      f"(continuous batching keeps slots busy)")
for i, o in enumerate(outs[:3]):
    print(f"request {i}: {o[:12]} ...")
