"""End-to-end training driver: smollm-135m (the assigned ~100M-class arch)
on the synthetic pipeline, with checkpoint/resume + compression enabled.

Demo default (CPU-sized):   PYTHONPATH=src python examples/train_smollm.py
Full 135M, few hundred steps (the deliverable command; hours on CPU, minutes
on a real accelerator):
    PYTHONPATH=src python examples/train_smollm.py --full --steps 300
"""

import argparse
import dataclasses

import jax.numpy as jnp

from repro.configs import get_config, smoke_config
from repro.data import DataConfig
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainConfig


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="the real 135M config (30L x 576)")
    p.add_argument("--steps", type=int, default=120)
    p.add_argument("--seq-len", type=int, default=256)
    p.add_argument("--global-batch", type=int, default=8)
    p.add_argument("--checkpoint-dir", default="/tmp/repro-smollm-ckpt")
    args = p.parse_args()

    if args.full:
        cfg = dataclasses.replace(get_config("smollm-135m"),
                                  dtype=jnp.float32)
    else:
        # same family, laptop-sized: 6L x 192 (~8M params)
        cfg = dataclasses.replace(
            smoke_config("smollm-135m"), n_layers=6, d_model=192, n_heads=6,
            n_kv=2, d_ff=512, vocab=4096, head_dim=32, dtype=jnp.float32)

    trainer = Trainer(
        cfg,
        AdamWConfig(lr=6e-4, warmup_steps=max(args.steps // 20, 1),
                    total_steps=args.steps),
        TrainConfig(steps=args.steps, microbatches=2, compress_grads=True,
                    checkpoint_dir=args.checkpoint_dir, checkpoint_every=50,
                    log_every=10),
        DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                   global_batch=args.global_batch),
    )
    res = trainer.run()
    h = res["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{len(h)} steps  (resume-safe: rerun this command to continue "
          f"from {args.checkpoint_dir})")


if __name__ == "__main__":
    main()
